//! Checkpoint/resume for registry experiments.
//!
//! Every experiment run through [`crate::experiments::run_selected`] gets a
//! [`Checkpoint`] journaling its completed work units ("cells": one
//! optimizer run, or one scenario cell) as JSON lines under
//! `<out_dir>/checkpoints/<id>.jsonl`. A run killed mid-flight and
//! restarted with `--resume` replays journaled cells instead of
//! re-evaluating them, and a fully completed experiment replays its stored
//! report byte-identically (reports are deterministic given the seed when
//! `--stable` hides wall-clock columns — enforced by
//! `rust/tests/checkpoint_resume.rs`).
//!
//! Two persistence layers:
//!
//! * **Cell journal** — append-only JSONL, one `{"k": key, "v": value}`
//!   object per line, flushed per cell so a kill loses at most the cell in
//!   flight. Unparseable trailing lines (a mid-write kill) are skipped on
//!   load. The special `__report__` cell marks experiment completion.
//! * **Eval memo** — the coordinator's sharded evaluation cache (PR 1)
//!   persisted per problem configuration ([`JointProblem::config_key`])
//!   into `<id>.memo.jsonl` (append-only, new entries only per absorb),
//!   so re-running an *interrupted* cell on resume starts with every
//!   previously evaluated design warm. The accuracy-proxy memo rides
//!   along in `<id>.acc.jsonl`, keyed by [`JointProblem::acc_scope`]
//!   (space variant + memory tech + backend — the proxy is purely
//!   design-keyed, so it is shared across workload subsets). Preloading
//!   never changes scores (they are deterministic per design), only the
//!   number of evaluator invocations, so experiments whose reports print
//!   eval counts simply don't opt in.

use crate::coordinator::{Evaluations, JointProblem};
use crate::model::Metrics;
use crate::orchestrator::lease::CellClaims;
use crate::report::Report;
use crate::search::OptResult;
use crate::space::Design;
use crate::util::fault;
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// Journal key marking a completed experiment (stores the full report).
const REPORT_KEY: &str = "__report__";

/// Journal key pinning the run configuration the journal was written with.
const CONFIG_KEY: &str = "__config__";

/// File name of the **shared cross-experiment cell namespace** under
/// `<out_dir>/checkpoints/`. Searches that are bit-identical across
/// experiments — today the specialist bounds, keyed `bound:<set>:<w>`
/// (same problem, same GA config, same [`crate::scenarios::bound_seed`]
/// stream in `genmatrix`, `genmatrix_k`, `transfer` and `pareto`) — are
/// journaled here once and replayed by every later experiment of the
/// same run, so `imcopt run --all` stops paying for identical bounds
/// twice. The file is a pure cache: every value is *also* journaled
/// under the owning experiment's own key, so per-experiment journals
/// stay standalone-resumable, and the cache is discarded whenever the
/// bound configuration changes ([`Checkpoint::bind_config`]) or a
/// non-resume sweep starts ([`Checkpoint::reset_shared`]).
const SHARED_FILE: &str = "shared_bounds.jsonl";

/// Remove a file, treating "not found" as success and surfacing anything
/// else (a journal we cannot discard must not be silently appended to).
fn remove_if_exists(path: &Path) -> Result<()> {
    match std::fs::remove_file(path) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
        Err(e) => Err(e).with_context(|| format!("removing {}", path.display())),
    }
}

/// Load a `{"k": ..., "v": ...}`-per-line JSONL cell file into a map.
/// A missing file is an empty map (cold start); a kill mid-append can
/// truncate the final line, so unparseable lines are skipped rather than
/// poisoning the resume. Any other I/O error surfaces.
fn load_cells(path: &Path) -> Result<BTreeMap<String, Json>> {
    let mut cells = BTreeMap::new();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(cells),
        Err(e) => {
            return Err(e).with_context(|| format!("reading journal {}", path.display()))
        }
    };
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let parsed = match json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                eprintln!(
                    "[checkpoint] skipping corrupt journal line in {}: {e}",
                    path.display()
                );
                continue;
            }
        };
        if let (Some(k), Some(v)) = (parsed.get("k").and_then(|k| k.as_str()), parsed.get("v"))
        {
            cells.insert(k.to_string(), v.clone());
        }
    }
    Ok(cells)
}

/// Open a journal for a crash-consistent append: the fault-injection point
/// fires first (so an injected IO fault never half-writes), then a
/// truncated tail left by a previously killed writer is newline-terminated
/// so this append starts on a fresh line (the loader skips the corrupt
/// line; it never merges with ours). Callers `write_all` whole lines and
/// finish with `sync_data` so a kill after the call loses nothing.
fn open_journal_for_append(path: &Path, kind: &str) -> Result<std::fs::File> {
    fault::point(&format!("journal:{kind}"))
        .with_context(|| format!("appending to {}", path.display()))?;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .read(true)
        .open(path)
        .with_context(|| format!("opening {kind} journal {}", path.display()))?;
    let len = f
        .metadata()
        .with_context(|| format!("inspecting {}", path.display()))?
        .len();
    if len > 0 {
        use std::io::{Read as _, Seek as _, SeekFrom};
        f.seek(SeekFrom::End(-1)).context("seeking journal tail")?;
        let mut last = [0u8; 1];
        f.read_exact(&mut last).context("reading journal tail")?;
        if last[0] != b'\n' {
            eprintln!(
                "[checkpoint] repairing truncated tail of {}",
                path.display()
            );
            f.write_all(b"\n").context("repairing journal tail")?;
        }
    }
    Ok(f)
}

/// Incrementally fold journal lines appended since `offset` into `map`,
/// advancing `offset` past the last *complete* line (a concurrent writer
/// may be mid-append; its partial tail is left for the next refresh).
fn refresh_cells(
    path: &Path,
    offset: &mut u64,
    map: &mut BTreeMap<String, Json>,
) -> Result<()> {
    use std::io::{Read as _, Seek as _, SeekFrom};
    let mut f = match std::fs::File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => {
            return Err(e).with_context(|| format!("refreshing {}", path.display()))
        }
    };
    let len = f
        .metadata()
        .with_context(|| format!("inspecting {}", path.display()))?
        .len();
    if len <= *offset {
        return Ok(());
    }
    f.seek(SeekFrom::Start(*offset))
        .context("seeking journal refresh offset")?;
    let mut buf = Vec::with_capacity((len - *offset) as usize);
    f.read_to_end(&mut buf)
        .with_context(|| format!("reading {}", path.display()))?;
    let Some(last_newline) = buf.iter().rposition(|&b| b == b'\n') else {
        return Ok(());
    };
    let complete = last_newline + 1;
    for line in String::from_utf8_lossy(&buf[..complete]).lines() {
        if line.trim().is_empty() {
            continue;
        }
        let parsed = match json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                eprintln!(
                    "[checkpoint] skipping corrupt journal line in {}: {e}",
                    path.display()
                );
                continue;
            }
        };
        if let (Some(k), Some(v)) = (parsed.get("k").and_then(|k| k.as_str()), parsed.get("v"))
        {
            map.insert(k.to_string(), v.clone());
        }
    }
    *offset += complete as u64;
    Ok(())
}

/// Run a cell's compute closure with panic isolation: a panicking cell
/// becomes an `Err` naming the cell and the panic message, so the caller
/// (the experiment runner) can retry or quarantine the experiment instead
/// of unwinding across the whole sweep.
fn run_compute(key: &str, compute: impl FnOnce() -> Result<Json>) -> Result<Json> {
    fault::point(&format!("cell:{key}"))
        .with_context(|| format!("computing cell '{key}'"))?;
    let _span = crate::telemetry::span(crate::telemetry::Stage::CellCompute);
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(compute)) {
        Ok(r) => r.with_context(|| format!("computing cell '{key}'")),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            bail!("cell '{key}' panicked: {msg}")
        }
    }
}

/// Per-experiment checkpoint state. See the module docs.
#[derive(Debug, Default)]
pub struct Checkpoint {
    /// Journal file; `None` = persistence disabled (unit tests, library
    /// callers of `experiments::run`).
    journal_path: Option<PathBuf>,
    memo_path: Option<PathBuf>,
    acc_path: Option<PathBuf>,
    /// Cross-experiment shared namespace (see [`SHARED_FILE`]); loaded at
    /// open, but consulted only once [`Checkpoint::bind_config`] has
    /// verified the stored configuration matches this run.
    shared_path: Option<PathBuf>,
    shared: BTreeMap<String, Json>,
    shared_active: bool,
    cells: BTreeMap<String, Json>,
    /// scope (problem config key) → (linear index → decoded
    /// [`Evaluations`]); decoded once at load/absorb time so warming a
    /// problem is a clone, not a JSON decode.
    memo: BTreeMap<String, BTreeMap<u64, Evaluations>>,
    /// scope ([`JointProblem::acc_scope`]) → ((rows, cols, bits,
    /// perturbation id) indices → memoized per-layer eps of the accuracy
    /// proxy; id 0 = nominal, ids ≥ 1 = robustness-ensemble members).
    acc: BTreeMap<String, BTreeMap<(u16, u16, u16, u16), f64>>,
    computed: usize,
    reused: usize,
    /// Simulated-kill hook for the resume tests: the cell *after* this
    /// many fresh computations errors out instead of running, leaving the
    /// journal exactly as a hard kill would.
    pub abort_after_cells: Option<usize>,
    /// Cross-process cell-claim coordinator (multi-worker runs); `None`
    /// (the default) gives plain single-process semantics.
    coord: Option<Arc<CellClaims>>,
    /// Byte offsets up to which the journal / shared file have been folded
    /// into `cells` / `shared` by [`refresh_cells`] — coordinated runs
    /// re-read only the suffix another worker appended.
    journal_offset: u64,
    shared_offset: u64,
}

impl Checkpoint {
    /// A checkpoint that journals nothing (every cell recomputes).
    pub fn disabled() -> Checkpoint {
        Checkpoint::default()
    }

    /// Open (or start) the checkpoint for one experiment under
    /// `<out_dir>/checkpoints/`. With `resume` the existing journal and
    /// memo are loaded; without it they are discarded so the run starts
    /// cold.
    pub fn for_experiment(out_dir: &Path, id: &str, resume: bool) -> Result<Checkpoint> {
        let dir = out_dir.join("checkpoints");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let journal_path = dir.join(format!("{id}.jsonl"));
        let memo_path = dir.join(format!("{id}.memo.jsonl"));
        let acc_path = dir.join(format!("{id}.acc.jsonl"));
        let shared_path = dir.join(SHARED_FILE);
        let mut ckpt = Checkpoint {
            journal_path: Some(journal_path.clone()),
            memo_path: Some(memo_path.clone()),
            acc_path: Some(acc_path.clone()),
            shared_path: Some(shared_path.clone()),
            ..Checkpoint::default()
        };
        if resume {
            ckpt.load_journal(&journal_path)?;
            ckpt.load_memo(&memo_path)?;
            ckpt.load_acc(&acc_path)?;
        } else {
            remove_if_exists(&journal_path)?;
            remove_if_exists(&memo_path)?;
            remove_if_exists(&acc_path)?;
        }
        // the shared namespace is a cache shared by the *other* experiments
        // of this run, so it is loaded even on a cold open (run_selected
        // discards it once per non-resume sweep via `reset_shared`)
        ckpt.shared = load_cells(&shared_path)?;
        Ok(ckpt)
    }

    /// Discard the shared cross-experiment namespace under `out_dir`.
    /// Called once per non-resume `run_selected` sweep, so a fresh sweep
    /// never reuses another sweep's bounds while the experiments *within*
    /// it still share theirs.
    pub fn reset_shared(out_dir: &Path) -> Result<()> {
        remove_if_exists(&out_dir.join("checkpoints").join(SHARED_FILE))
    }

    /// Pre-initialize the shared namespace for `config`. The supervisor
    /// calls this once before spawning workers, closing the window where
    /// two workers racing through [`Checkpoint::bind_config`] would both
    /// truncate-rewrite the cache file (and could clobber a bound the
    /// other had already published). Idempotent: an already-matching
    /// cache is left untouched.
    pub fn ensure_shared(out_dir: &Path, config: &Json) -> Result<()> {
        let dir = out_dir.join("checkpoints");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join(SHARED_FILE);
        if load_cells(&path)?.get(CONFIG_KEY) == Some(config) {
            return Ok(());
        }
        let line = Json::obj(vec![
            ("k", Json::Str(CONFIG_KEY.to_string())),
            ("v", config.clone()),
        ])
        .to_string();
        crate::util::write_atomic(&path, &(line + "\n"))
            .with_context(|| format!("initializing {}", path.display()))
    }

    fn load_journal(&mut self, path: &Path) -> Result<()> {
        self.cells = load_cells(path)?;
        Ok(())
    }

    fn load_memo(&mut self, path: &Path) -> Result<()> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading memo {}", path.display()))
            }
        };
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            // same mid-append kill tolerance as the cell journal
            let Ok(entry) = json::parse(line) else {
                eprintln!(
                    "[checkpoint] skipping corrupt memo line in {}",
                    path.display()
                );
                continue;
            };
            if let (Some(scope), Some(key), Some(v)) = (
                entry.get("s").and_then(|s| s.as_str()),
                entry.get("k").and_then(|k| k.as_str()),
                entry.get("v"),
            ) {
                if let (Ok(idx), Ok(ev)) = (key.parse::<u64>(), evaluation_from_json(v))
                {
                    self.memo
                        .entry(scope.to_string())
                        .or_default()
                        .insert(idx, ev);
                }
            }
        }
        Ok(())
    }

    fn load_acc(&mut self, path: &Path) -> Result<()> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading acc memo {}", path.display()))
            }
        };
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            // same mid-append kill tolerance as the cell journal
            let Ok(entry) = json::parse(line) else {
                eprintln!(
                    "[checkpoint] skipping corrupt acc memo line in {}",
                    path.display()
                );
                continue;
            };
            if let (Some(scope), Some(key), Some(v)) = (
                entry.get("s").and_then(|s| s.as_str()),
                entry.get("k").and_then(|k| k.as_str()),
                entry.get("v").and_then(|v| v.as_f64_lenient()),
            ) {
                if let Some(k) = parse_acc_key(key) {
                    self.acc.entry(scope.to_string()).or_default().insert(k, v);
                }
            }
        }
        Ok(())
    }

    /// Whether cells persist across processes.
    pub fn is_persistent(&self) -> bool {
        self.journal_path.is_some()
    }

    /// Journaled cells replayed by this process.
    pub fn reused(&self) -> usize {
        self.reused
    }

    /// Cells computed fresh (and journaled) by this process.
    pub fn computed(&self) -> usize {
        self.computed
    }

    /// Journaled value for `key`, if any.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.cells.get(key)
    }

    fn append_journal(&self, key: &str, value: &Json) -> Result<()> {
        let Some(path) = &self.journal_path else {
            return Ok(());
        };
        let line = Json::obj(vec![
            ("k", Json::Str(key.to_string())),
            ("v", value.clone()),
        ])
        .to_string();
        let mut f = open_journal_for_append(path, "cells")?;
        f.write_all((line + "\n").as_bytes())
            .context("appending journal cell")?;
        f.sync_data().context("syncing journal")?;
        crate::telemetry::journal_appends(1);
        crate::telemetry::journal_sync();
        Ok(())
    }

    /// Attach a cross-process claim coordinator: from here on, a cell miss
    /// first claims the key's lease so only one worker computes it while
    /// the others wait for the value to appear in the journal (or steal
    /// the lease if the holder dies). No-op on non-persistent checkpoints
    /// — without a journal there is nothing for other workers to read.
    pub fn coordinate(&mut self, claims: Arc<CellClaims>) {
        if self.journal_path.is_some() {
            self.coord = Some(claims);
        }
    }

    fn refresh_journal(&mut self) -> Result<()> {
        if let Some(path) = self.journal_path.clone() {
            let mut off = self.journal_offset;
            refresh_cells(&path, &mut off, &mut self.cells)?;
            self.journal_offset = off;
        }
        Ok(())
    }

    fn refresh_shared(&mut self) -> Result<()> {
        if let Some(path) = self.shared_path.clone() {
            let mut off = self.shared_offset;
            refresh_cells(&path, &mut off, &mut self.shared)?;
            self.shared_offset = off;
        }
        Ok(())
    }

    /// Re-read both files and return the journaled (or shared) value for
    /// `key` if another worker has produced it meanwhile. Counts as a
    /// reuse; shared hits are copied into this journal so it stays
    /// standalone-resumable.
    fn poll_other_workers(
        &mut self,
        key: &str,
        shared_key: Option<&str>,
    ) -> Result<Option<Json>> {
        self.refresh_journal()?;
        if self.shared_active {
            self.refresh_shared()?;
        }
        if let Some(v) = self.cells.get(key).cloned() {
            self.reused += 1;
            crate::telemetry::cell_reused();
            if let Some(sk) = shared_key {
                self.publish_shared(sk, &v)?;
            }
            return Ok(Some(v));
        }
        if let Some(sk) = shared_key {
            if self.shared_active {
                if let Some(v) = self.shared.get(sk).cloned() {
                    self.append_journal(key, &v)?;
                    self.cells.insert(key.to_string(), v.clone());
                    self.reused += 1;
                    crate::telemetry::cell_reused();
                    return Ok(Some(v));
                }
            }
        }
        Ok(None)
    }

    /// Return the journaled value for `key`, computing, journaling and
    /// flushing it on a miss. This is the resume granularity: everything
    /// an experiment routes through `cell` survives a kill (experiments
    /// that do are marked `cell` in the catalog — see
    /// [`crate::experiments::Granularity`]).
    ///
    /// ```
    /// use imcopt::experiments::checkpoint::Checkpoint;
    /// use imcopt::util::json::Json;
    ///
    /// let mut ckpt = Checkpoint::disabled(); // in-memory only
    /// let v = ckpt.cell("demo", || Ok(Json::Num(1.5))).unwrap();
    /// assert_eq!(v, Json::Num(1.5));
    /// // a journaled key replays without recomputing
    /// let v = ckpt.cell("demo", || unreachable!()).unwrap();
    /// assert_eq!(v, Json::Num(1.5));
    /// assert_eq!((ckpt.computed(), ckpt.reused()), (1, 1));
    /// ```
    pub fn cell(
        &mut self,
        key: &str,
        compute: impl FnOnce() -> Result<Json>,
    ) -> Result<Json> {
        self.cell_inner(key, None, compute)
    }

    /// The common miss path of [`Checkpoint::cell`] and
    /// [`Checkpoint::shared_cell`]. When coordinated (multi-worker), a
    /// miss claims the key's lease before computing; losing the claim
    /// means another live worker is computing the same cell, so this
    /// worker polls the journal until the value lands (or the holder's
    /// lease goes stale and the claim is stolen). Winning the claim
    /// re-checks the journal first — the previous holder may have
    /// journaled the value just before dying.
    fn cell_inner(
        &mut self,
        key: &str,
        shared_key: Option<&str>,
        compute: impl FnOnce() -> Result<Json>,
    ) -> Result<Json> {
        if let Some(v) = self.cells.get(key).cloned() {
            self.reused += 1;
            crate::telemetry::cell_reused();
            if let Some(sk) = shared_key {
                // publish a replayed value too, so later experiments of a
                // partially-resumed sweep reuse it instead of recomputing
                self.publish_shared(sk, &v)?;
            }
            return Ok(v);
        }
        if let Some(sk) = shared_key {
            if self.shared_active {
                if let Some(v) = self.shared.get(sk).cloned() {
                    self.append_journal(key, &v)?;
                    self.cells.insert(key.to_string(), v.clone());
                    self.reused += 1;
                    crate::telemetry::cell_reused();
                    return Ok(v);
                }
            }
        }
        if let Some(n) = self.abort_after_cells {
            if self.computed >= n {
                bail!("checkpoint: simulated kill after {n} fresh cells");
            }
        }
        if let Some(claims) = self.coord.clone() {
            let claim_key = shared_key.unwrap_or(key).to_string();
            let mut compute = Some(compute);
            loop {
                match claims.try_claim(&claim_key)? {
                    Some(guard) => {
                        if let Some(v) = self.poll_other_workers(key, shared_key)? {
                            return Ok(v);
                        }
                        let value = run_compute(
                            key,
                            compute.take().expect("claim loop computes once"),
                        )?;
                        self.append_journal(key, &value)?;
                        self.cells.insert(key.to_string(), value.clone());
                        if let Some(sk) = shared_key {
                            self.publish_shared(sk, &value)?;
                        }
                        self.computed += 1;
                        crate::telemetry::cell_computed();
                        guard.release();
                        return Ok(value);
                    }
                    None => {
                        std::thread::sleep(claims.poll_interval());
                        if let Some(v) = self.poll_other_workers(key, shared_key)? {
                            return Ok(v);
                        }
                    }
                }
            }
        }
        let value = run_compute(key, compute)?;
        self.append_journal(key, &value)?;
        self.cells.insert(key.to_string(), value.clone());
        if let Some(sk) = shared_key {
            self.publish_shared(sk, &value)?;
        }
        self.computed += 1;
        crate::telemetry::cell_computed();
        Ok(value)
    }

    fn publish_shared(&mut self, shared_key: &str, v: &Json) -> Result<()> {
        if self.shared_active && !self.shared.contains_key(shared_key) {
            self.append_shared(shared_key, v)?;
            self.shared.insert(shared_key.to_string(), v.clone());
        }
        Ok(())
    }

    /// Bind this checkpoint to the run configuration. A fresh journal
    /// records it; a resumed journal with a *different* stored
    /// configuration (seed, budget, topk, backend, stable mode) is an
    /// error — replaying its cells would silently mix results from two
    /// configurations into one report.
    ///
    /// Binding also activates the shared cross-experiment namespace:
    /// its stored configuration must match too, but since the shared
    /// file is only a cache backed by the per-experiment journals, a
    /// mismatch just discards it instead of erroring.
    pub fn bind_config(&mut self, config: &Json) -> Result<()> {
        if let Some(stored) = self.cells.get(CONFIG_KEY) {
            anyhow::ensure!(
                stored == config,
                "checkpoint journal was written with a different configuration \
                 ({stored}) than this run ({config}); match the original flags \
                 or rerun without --resume"
            );
            self.activate_shared(config)?;
            return Ok(());
        }
        let value = config.clone();
        self.append_journal(CONFIG_KEY, &value)?;
        self.cells.insert(CONFIG_KEY.to_string(), value);
        self.activate_shared(config)?;
        Ok(())
    }

    /// Engage the shared namespace under `config`, discarding any cells
    /// cached under a different configuration (see [`SHARED_FILE`]).
    fn activate_shared(&mut self, config: &Json) -> Result<()> {
        let Some(path) = self.shared_path.clone() else {
            return Ok(());
        };
        match self.shared.get(CONFIG_KEY) {
            Some(stored) if stored == config => {}
            _ => {
                // stale or uninitialized cache: restart it for this config
                self.shared.clear();
                let line = Json::obj(vec![
                    ("k", Json::Str(CONFIG_KEY.to_string())),
                    ("v", config.clone()),
                ])
                .to_string();
                crate::util::write_atomic(&path, &(line + "\n"))
                    .with_context(|| format!("initializing {}", path.display()))?;
                self.shared_offset = 0;
                self.shared.insert(CONFIG_KEY.to_string(), config.clone());
            }
        }
        self.shared_active = true;
        Ok(())
    }

    fn append_shared(&self, key: &str, value: &Json) -> Result<()> {
        let Some(path) = &self.shared_path else {
            return Ok(());
        };
        let line = Json::obj(vec![
            ("k", Json::Str(key.to_string())),
            ("v", value.clone()),
        ])
        .to_string();
        let mut f = open_journal_for_append(path, "shared")?;
        f.write_all((line + "\n").as_bytes())
            .context("appending shared cell")?;
        f.sync_data().context("syncing shared journal")?;
        crate::telemetry::journal_appends(1);
        crate::telemetry::journal_sync();
        Ok(())
    }

    /// Like [`Checkpoint::cell`], but additionally published under
    /// `shared_key` in the cross-experiment namespace (when active — see
    /// [`Checkpoint::bind_config`]). Resolution order: this experiment's
    /// own journal (standalone resume), then the shared cache (another
    /// experiment of the same run computed the identical search — the
    /// value is copied into this journal so it stays standalone), then
    /// `compute`. Shared hits count as reused, not computed.
    pub fn shared_cell(
        &mut self,
        key: &str,
        shared_key: &str,
        compute: impl FnOnce() -> Result<Json>,
    ) -> Result<Json> {
        self.cell_inner(key, Some(shared_key), compute)
    }

    /// Journal the finished experiment's report (completion marker).
    pub fn store_report(&mut self, report: &Report) -> Result<()> {
        let value = report.to_json();
        self.append_journal(REPORT_KEY, &value)?;
        self.cells.insert(REPORT_KEY.to_string(), value);
        Ok(())
    }

    /// The stored report of a completed experiment, if present.
    pub fn stored_report(&self) -> Result<Option<Report>> {
        self.cells
            .get(REPORT_KEY)
            .map(Report::from_json)
            .transpose()
    }

    /// Preload `problem`'s evaluation and accuracy-proxy memos from the
    /// persisted snapshots for its configuration; returns the number of
    /// evaluations imported.
    pub fn warm_problem(&self, problem: &JointProblem<'_>) -> usize {
        if let Some(entries) = self.acc.get(&problem.acc_scope()) {
            problem.preload_acc_cache(entries.iter().map(|(&k, &v)| (k, v)).collect());
        }
        let Some(entries) = self.memo.get(&problem.config_key()) else {
            return 0;
        };
        let n = entries.len();
        problem.preload_cache(entries.iter().map(|(&k, ev)| (k, ev.clone())).collect());
        n
    }

    /// Snapshot `problem`'s evaluation and accuracy-proxy memos into this
    /// checkpoint (keyed by the problem's configuration / acc scope),
    /// appending only the *new* entries to the memo files (JSONL, like
    /// the cell journal) — O(new entries), not O(total memo), per absorb.
    /// Call [`Checkpoint::warm_problem`] on the problem first (as every
    /// call site does): a problem whose cache is no larger than the
    /// stored scope is assumed already absorbed and skipped without
    /// snapshotting.
    pub fn absorb_problem(&mut self, problem: &JointProblem<'_>) -> Result<()> {
        self.absorb_eval_memo(problem)?;
        self.absorb_acc_memo(problem)
    }

    fn absorb_eval_memo(&mut self, problem: &JointProblem<'_>) -> Result<()> {
        let scope = problem.config_key();
        let known = self.memo.get(&scope).map(|m| m.len()).unwrap_or(0);
        if problem.cache_len() <= known {
            return Ok(());
        }
        let snapshot = problem.cache_snapshot();
        let map = self.memo.entry(scope.clone()).or_default();
        let mut fresh: Vec<u64> = Vec::new();
        for (k, ev) in snapshot {
            if !map.contains_key(&k) {
                map.insert(k, ev);
                fresh.push(k);
            }
        }
        if fresh.is_empty() {
            return Ok(());
        }
        let Some(path) = &self.memo_path else {
            return Ok(());
        };
        let map = &self.memo[&scope];
        let mut f = open_journal_for_append(path, "memo")?;
        for k in &fresh {
            let line = Json::obj(vec![
                ("s", Json::Str(scope.clone())),
                ("k", Json::Str(k.to_string())),
                ("v", evaluation_to_json(&map[k])),
            ])
            .to_string();
            f.write_all((line + "\n").as_bytes())
                .context("appending memo entry")?;
        }
        f.sync_data().context("syncing memo")?;
        crate::telemetry::journal_appends(fresh.len());
        crate::telemetry::journal_sync();
        Ok(())
    }

    fn absorb_acc_memo(&mut self, problem: &JointProblem<'_>) -> Result<()> {
        let scope = problem.acc_scope();
        let known = self.acc.get(&scope).map(|m| m.len()).unwrap_or(0);
        if problem.acc_cache_len() <= known {
            return Ok(());
        }
        let snapshot = problem.acc_snapshot();
        let map = self.acc.entry(scope.clone()).or_default();
        let mut fresh: Vec<((u16, u16, u16, u16), f64)> = Vec::new();
        for (k, v) in snapshot {
            if !map.contains_key(&k) {
                map.insert(k, v);
                fresh.push((k, v));
            }
        }
        if fresh.is_empty() {
            return Ok(());
        }
        let Some(path) = &self.acc_path else {
            return Ok(());
        };
        let mut f = open_journal_for_append(path, "acc")?;
        for (k, v) in &fresh {
            let line = Json::obj(vec![
                ("s", Json::Str(scope.clone())),
                ("k", Json::Str(acc_key_to_string(*k))),
                ("v", Json::f64(*v)),
            ])
            .to_string();
            f.write_all((line + "\n").as_bytes())
                .context("appending acc memo entry")?;
        }
        f.sync_data().context("syncing acc memo")?;
        crate::telemetry::journal_appends(fresh.len());
        crate::telemetry::journal_sync();
        Ok(())
    }
}

/// `(rows, cols, bits, perturbation id)` design-index key ↔ string (acc
/// memo file). Perturbation id 0 (the nominal path) keeps the legacy
/// three-component "r,c,b" spelling, so default (non-robust) runs write
/// byte-identical memo files to every earlier version; ensemble members
/// serialize as "r,c,b,p". The parser accepts both.
fn acc_key_to_string(k: (u16, u16, u16, u16)) -> String {
    if k.3 == 0 {
        format!("{},{},{}", k.0, k.1, k.2)
    } else {
        format!("{},{},{},{}", k.0, k.1, k.2, k.3)
    }
}

fn parse_acc_key(s: &str) -> Option<(u16, u16, u16, u16)> {
    let mut it = s.split(',').map(|p| p.parse::<u16>().ok());
    match (it.next(), it.next(), it.next(), it.next(), it.next()) {
        (Some(Some(r)), Some(Some(c)), Some(Some(b)), None, None) => Some((r, c, b, 0)),
        (Some(Some(r)), Some(Some(c)), Some(Some(b)), Some(Some(p)), None) => {
            Some((r, c, b, p))
        }
        _ => None,
    }
}

// ---- JSON codecs -----------------------------------------------------------
//
// Finite floats round-trip bit-exactly through `Json::f64`; designs are
// index vectors. These are the primitives `common::ga_cell` and the
// experiment modules journal.

/// Serialize a design (its index vector).
pub fn design_to_json(d: &Design) -> Json {
    Json::Arr(d.0.iter().map(|&v| Json::Num(v as f64)).collect())
}

/// Deserialize a design.
pub fn design_from_json(v: &Json) -> Result<Design> {
    let arr = v.as_arr().context("design: expected an array")?;
    let idx: Vec<u16> = arr
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as u16)
                .context("design: expected a number")
        })
        .collect::<Result<_>>()?;
    Ok(Design(idx))
}

/// Serialize a full optimizer result (journal cell payload).
pub fn opt_result_to_json(r: &OptResult) -> Json {
    Json::obj(vec![
        ("algorithm", Json::Str(r.algorithm.clone())),
        ("best", design_to_json(&r.best)),
        ("best_score", Json::f64(r.best_score)),
        (
            "history",
            Json::Arr(r.history.iter().map(|&x| Json::f64(x)).collect()),
        ),
        (
            "top",
            Json::Arr(
                r.top
                    .iter()
                    .map(|(d, s)| Json::Arr(vec![design_to_json(d), Json::f64(*s)]))
                    .collect(),
            ),
        ),
        ("evals", Json::Num(r.evals as f64)),
        ("wall_us", Json::Num(r.wall.as_micros() as f64)),
    ])
}

/// Deserialize an optimizer result journaled by [`opt_result_to_json`].
pub fn opt_result_from_json(v: &Json) -> Result<OptResult> {
    let f64_field = |key: &str| -> Result<f64> {
        v.get(key)
            .and_then(|x| x.as_f64_lenient())
            .with_context(|| format!("opt result: missing '{key}'"))
    };
    let history = v
        .get("history")
        .and_then(|h| h.as_arr())
        .context("opt result: missing 'history'")?
        .iter()
        .map(|x| x.as_f64_lenient().context("history: expected a number"))
        .collect::<Result<Vec<f64>>>()?;
    let top = v
        .get("top")
        .and_then(|t| t.as_arr())
        .context("opt result: missing 'top'")?
        .iter()
        .map(|pair| -> Result<(Design, f64)> {
            let pair = pair.as_arr().context("top entry: expected a pair")?;
            anyhow::ensure!(pair.len() == 2, "top entry: expected [design, score]");
            Ok((
                design_from_json(&pair[0])?,
                pair[1].as_f64_lenient().context("top score")?,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(OptResult {
        algorithm: v
            .get("algorithm")
            .and_then(|a| a.as_str())
            .context("opt result: missing 'algorithm'")?
            .to_string(),
        best: design_from_json(v.get("best").context("opt result: missing 'best'")?)?,
        best_score: f64_field("best_score")?,
        history,
        top,
        evals: f64_field("evals")? as usize,
        wall: Duration::from_micros(f64_field("wall_us")? as u64),
    })
}

/// Serialize one memoized evaluation record (compact keys: the memo holds
/// thousands of these).
pub fn evaluation_to_json(ev: &Evaluations) -> Json {
    Json::obj(vec![
        (
            "m",
            Json::Arr(
                ev.metrics
                    .iter()
                    .map(|m| {
                        Json::Arr(vec![
                            Json::f64(m.energy),
                            Json::f64(m.latency),
                            Json::f64(m.area),
                            Json::Bool(m.feasible),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "a",
            match &ev.accuracies {
                Some(accs) => {
                    Json::Arr(accs.iter().map(|&x| Json::f64(x)).collect())
                }
                None => Json::Null,
            },
        ),
        ("s", Json::f64(ev.score)),
    ])
}

/// Deserialize a memoized evaluation record.
pub fn evaluation_from_json(v: &Json) -> Result<Evaluations> {
    let metrics = v
        .get("m")
        .and_then(|m| m.as_arr())
        .context("evaluation: missing 'm'")?
        .iter()
        .map(|m| -> Result<Metrics> {
            let m = m.as_arr().context("metrics: expected an array")?;
            anyhow::ensure!(m.len() == 4, "metrics: expected 4 fields");
            Ok(Metrics {
                energy: m[0].as_f64_lenient().context("energy")?,
                latency: m[1].as_f64_lenient().context("latency")?,
                area: m[2].as_f64_lenient().context("area")?,
                feasible: matches!(m[3], Json::Bool(true)),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let accuracies = match v.get("a") {
        Some(Json::Arr(accs)) => Some(
            accs.iter()
                .map(|x| x.as_f64_lenient().context("accuracy"))
                .collect::<Result<Vec<f64>>>()?,
        ),
        _ => None,
    };
    Ok(Evaluations {
        metrics,
        accuracies,
        score: v
            .get("s")
            .and_then(|s| s.as_f64_lenient())
            .context("evaluation: missing 's'")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EvalBackend;
    use crate::model::MemoryTech;
    use crate::objective::{Aggregation, Objective, ObjectiveKind};
    use crate::space::SearchSpace;
    use crate::util::rng::Rng;
    use crate::workloads::WorkloadSet;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("imcopt-ckpt-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn cells_journal_and_reload() {
        let dir = tmp("cells");
        let mut calls = 0usize;
        {
            let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
            assert!(ck.is_persistent());
            let v = ck
                .cell("a", || {
                    calls += 1;
                    Ok(Json::Num(1.5))
                })
                .unwrap();
            assert_eq!(v, Json::Num(1.5));
            // same-process hit
            ck.cell("a", || panic!("must not recompute")).unwrap();
            assert_eq!(ck.computed(), 1);
            assert_eq!(ck.reused(), 1);
        }
        assert_eq!(calls, 1);
        // resumed process replays the journaled value
        let mut ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        let v = ck.cell("a", || panic!("must not recompute")).unwrap();
        assert_eq!(v, Json::Num(1.5));
        assert_eq!(ck.reused(), 1);
        // non-resume opens discard the journal
        let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
        let v = ck.cell("a", || Ok(Json::Num(2.5))).unwrap();
        assert_eq!(v, Json::Num(2.5));
    }

    #[test]
    fn corrupt_trailing_line_is_skipped() {
        let dir = tmp("corrupt");
        {
            let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
            ck.cell("good", || Ok(Json::Bool(true))).unwrap();
        }
        // simulate a kill mid-append
        let journal = dir.join("checkpoints/demo.jsonl");
        let mut text = std::fs::read_to_string(&journal).unwrap();
        text.push_str("{\"k\": \"bad\", \"v\": [1, 2");
        std::fs::write(&journal, text).unwrap();
        let mut ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        assert_eq!(ck.get("good"), Some(&Json::Bool(true)));
        assert!(ck.get("bad").is_none());
        // the damaged key recomputes cleanly
        ck.cell("bad", || Ok(Json::Num(3.0))).unwrap();
    }

    #[test]
    fn append_repairs_truncated_tail_before_writing() {
        let dir = tmp("tail-repair");
        {
            let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
            ck.cell("one", || Ok(Json::Num(1.0))).unwrap();
        }
        // a killed writer leaves a partial line with no terminator
        let journal = dir.join("checkpoints/demo.jsonl");
        let mut f = std::fs::OpenOptions::new().append(true).open(&journal).unwrap();
        use std::io::Write as _;
        f.write_all(b"{\"k\": \"partial\", \"v\": [1,").unwrap();
        drop(f);
        // the next append must newline-terminate the partial line first,
        // so its own line never merges with the corpse
        let mut ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        ck.cell("two", || Ok(Json::Num(2.0))).unwrap();
        let ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        assert_eq!(ck.get("one"), Some(&Json::Num(1.0)));
        assert_eq!(ck.get("two"), Some(&Json::Num(2.0)));
        assert!(ck.get("partial").is_none());
    }

    #[test]
    fn shared_journal_recovers_from_truncated_tail() {
        let dir = tmp("shared-tail");
        let cfg = Json::obj(vec![("seed", Json::Str("5".into()))]);
        {
            let mut a = Checkpoint::for_experiment(&dir, "expa", false).unwrap();
            a.bind_config(&cfg).unwrap();
            a.shared_cell("a:k", "bound:cnn4:1", || Ok(Json::Num(7.0))).unwrap();
        }
        // kill mid-append to the shared cache
        let shared = dir.join("checkpoints").join("shared_bounds.jsonl");
        let mut f = std::fs::OpenOptions::new().append(true).open(&shared).unwrap();
        use std::io::Write as _;
        f.write_all(b"{\"k\": \"bound:cnn4:2\", \"v\": 9").unwrap();
        drop(f);
        // the surviving complete lines (config + first bound) still load;
        // the truncated bound recomputes and appends on a fresh line
        let mut b = Checkpoint::for_experiment(&dir, "expb", false).unwrap();
        b.bind_config(&cfg).unwrap();
        let v = b
            .shared_cell("b:k", "bound:cnn4:1", || panic!("cached bound lost"))
            .unwrap();
        assert_eq!(v, Json::Num(7.0));
        let v = b
            .shared_cell("b:k2", "bound:cnn4:2", || Ok(Json::Num(9.5)))
            .unwrap();
        assert_eq!(v, Json::Num(9.5));
        // a third experiment sees both bounds intact after the repair
        let mut c = Checkpoint::for_experiment(&dir, "expc", false).unwrap();
        c.bind_config(&cfg).unwrap();
        assert_eq!(
            c.shared_cell("c:k", "bound:cnn4:2", || panic!("repaired bound lost"))
                .unwrap(),
            Json::Num(9.5)
        );
    }

    #[test]
    fn acc_memo_recovers_from_truncated_tail() {
        let dir = tmp("acc-tail");
        std::fs::create_dir_all(dir.join("checkpoints")).unwrap();
        let acc = dir.join("checkpoints/demo.acc.jsonl");
        std::fs::write(
            &acc,
            "{\"s\": \"scope\", \"k\": \"4,7,2\", \"v\": 0.125}\n\
             {\"s\": \"scope\", \"k\": \"5,8,3\", \"v\": 0.5",
        )
        .unwrap();
        let ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        let scope = ck.acc.get("scope").expect("intact acc entries load");
        assert_eq!(scope.get(&(4, 7, 2, 0)), Some(&0.125));
        assert!(
            !scope.contains_key(&(5, 8, 3, 0)),
            "truncated acc line must be skipped, not mis-parsed"
        );
    }

    #[test]
    fn panicking_cell_is_isolated_into_an_error() {
        let mut ck = Checkpoint::disabled();
        let err = ck.cell("p", || panic!("boom")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
        // the checkpoint stays usable after the isolated panic
        assert_eq!(ck.cell("q", || Ok(Json::Num(1.0))).unwrap(), Json::Num(1.0));
    }

    #[test]
    fn simulated_kill_stops_fresh_cells_only() {
        let dir = tmp("kill");
        {
            let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
            ck.abort_after_cells = Some(1);
            ck.cell("one", || Ok(Json::Num(1.0))).unwrap();
            let err = ck.cell("two", || Ok(Json::Num(2.0))).unwrap_err();
            assert!(format!("{err}").contains("simulated kill"));
        }
        let mut ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        // the journaled cell replays even with the hook armed
        ck.abort_after_cells = Some(0);
        assert_eq!(
            ck.cell("one", || panic!("journaled")).unwrap(),
            Json::Num(1.0)
        );
        assert!(ck.cell("two", || Ok(Json::Num(2.0))).is_err());
    }

    #[test]
    fn config_binding_rejects_mismatched_resume() {
        let dir = tmp("config");
        let cfg_a = Json::obj(vec![("seed", Json::Str("5".into()))]);
        let cfg_b = Json::obj(vec![("seed", Json::Str("6".into()))]);
        {
            let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
            ck.bind_config(&cfg_a).unwrap();
            ck.cell("one", || Ok(Json::Num(1.0))).unwrap();
        }
        // same config resumes fine and replays the cell
        let mut ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        ck.bind_config(&cfg_a).unwrap();
        assert_eq!(
            ck.cell("one", || panic!("journaled")).unwrap(),
            Json::Num(1.0)
        );
        // a different config must refuse to reuse the journal
        let mut ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        let err = ck.bind_config(&cfg_b).unwrap_err();
        assert!(format!("{err}").contains("different configuration"), "{err}");
        // a cold (non-resume) open discards the journal, so any config binds
        let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
        ck.bind_config(&cfg_b).unwrap();
    }

    #[test]
    fn shared_cells_cross_experiments_and_stay_standalone() {
        let dir = tmp("shared");
        let cfg = Json::obj(vec![("seed", Json::Str("5".into()))]);
        // experiment A computes the bound and publishes it
        {
            let mut a = Checkpoint::for_experiment(&dir, "expa", false).unwrap();
            a.bind_config(&cfg).unwrap();
            let v = a
                .shared_cell("expa:cnn4:bound:1", "bound:cnn4:1", || Ok(Json::Num(7.0)))
                .unwrap();
            assert_eq!(v, Json::Num(7.0));
            assert_eq!((a.computed(), a.reused()), (1, 0));
        }
        // experiment B under the same config reuses it without computing
        {
            let mut b = Checkpoint::for_experiment(&dir, "expb", false).unwrap();
            b.bind_config(&cfg).unwrap();
            let v = b
                .shared_cell("expb:cnn4:bound:1", "bound:cnn4:1", || {
                    panic!("must come from the shared namespace")
                })
                .unwrap();
            assert_eq!(v, Json::Num(7.0));
            assert_eq!((b.computed(), b.reused()), (0, 1));
        }
        // ... and B's own journal is standalone: a resume replays the cell
        // even after the shared namespace is discarded
        Checkpoint::reset_shared(&dir).unwrap();
        let mut b = Checkpoint::for_experiment(&dir, "expb", true).unwrap();
        b.bind_config(&cfg).unwrap();
        let v = b
            .shared_cell("expb:cnn4:bound:1", "bound:cnn4:1", || panic!("journaled"))
            .unwrap();
        assert_eq!(v, Json::Num(7.0));
    }

    #[test]
    fn shared_namespace_discards_on_config_change_and_without_binding() {
        let dir = tmp("shared-config");
        let cfg_a = Json::obj(vec![("seed", Json::Str("5".into()))]);
        let cfg_b = Json::obj(vec![("seed", Json::Str("6".into()))]);
        {
            let mut a = Checkpoint::for_experiment(&dir, "expa", false).unwrap();
            a.bind_config(&cfg_a).unwrap();
            a.shared_cell("k", "bound:cnn4:0", || Ok(Json::Num(1.0))).unwrap();
        }
        // a different configuration must not see the cached value
        {
            let mut b = Checkpoint::for_experiment(&dir, "expb", false).unwrap();
            b.bind_config(&cfg_b).unwrap();
            let v = b
                .shared_cell("k", "bound:cnn4:0", || Ok(Json::Num(2.0)))
                .unwrap();
            assert_eq!(v, Json::Num(2.0), "stale shared value leaked across configs");
            assert_eq!(b.computed(), 1);
        }
        // without bind_config the namespace stays inactive: no reads, no
        // writes, plain cell semantics
        let mut c = Checkpoint::for_experiment(&dir, "expc", false).unwrap();
        let v = c
            .shared_cell("k", "bound:cnn4:0", || Ok(Json::Num(3.0)))
            .unwrap();
        assert_eq!(v, Json::Num(3.0));
        // the b-config cache was not clobbered by the unbound write
        let mut d = Checkpoint::for_experiment(&dir, "expd", false).unwrap();
        d.bind_config(&cfg_b).unwrap();
        let v = d
            .shared_cell("k2", "bound:cnn4:0", || panic!("cached under cfg_b"))
            .unwrap();
        assert_eq!(v, Json::Num(2.0));
    }

    #[test]
    fn report_completion_marker_roundtrips() {
        let dir = tmp("report");
        let mut r = Report::new("demo", "title");
        let mut t = crate::util::table::Table::new("t", &["c"]);
        t.row(vec!["v".into()]);
        r.table(t);
        {
            let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
            assert!(ck.stored_report().unwrap().is_none());
            ck.store_report(&r).unwrap();
        }
        let ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        let back = ck.stored_report().unwrap().expect("report stored");
        assert_eq!(back.to_json().to_string(), r.to_json().to_string());
    }

    #[test]
    fn opt_result_codec_roundtrips_bit_exact() {
        let r = OptResult {
            algorithm: "4-phase GA (proposed)".into(),
            best: Design(vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 0]),
            best_score: 1.0 / 3.0,
            history: vec![f64::INFINITY, 2.5, 1.0 / 3.0],
            top: vec![
                (Design(vec![1; 10]), 1.0 / 3.0),
                (Design(vec![2; 10]), 0.7),
            ],
            evals: 480,
            wall: Duration::from_micros(123_456),
        };
        let j = opt_result_to_json(&r);
        let back = opt_result_from_json(&json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.algorithm, r.algorithm);
        assert_eq!(back.best, r.best);
        assert_eq!(back.best_score.to_bits(), r.best_score.to_bits());
        assert_eq!(back.history.len(), r.history.len());
        for (a, b) in back.history.iter().zip(&r.history) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.top, r.top);
        assert_eq!(back.evals, r.evals);
        assert_eq!(back.wall, r.wall);
    }

    fn problem<'a>(space: &'a SearchSpace, set: &'a WorkloadSet) -> JointProblem<'a> {
        JointProblem::with_backend(
            space,
            set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        )
    }

    #[test]
    fn memo_persists_and_warms_identical_configs() {
        let dir = tmp("memo");
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let mut rng = Rng::seed_from(33);
        let p = problem(&space, &set);
        let designs: Vec<Design> =
            (0..5).map(|_| p.random_candidate(&mut rng)).collect();
        let scores = p.score_batch(&designs);
        {
            let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
            ck.absorb_problem(&p).unwrap();
        }
        let ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        let q = problem(&space, &set);
        let imported = ck.warm_problem(&q);
        assert_eq!(imported, p.cache_len());
        let warm = q.score_batch(&designs);
        assert_eq!(q.evals(), 0, "memo must satisfy every lookup");
        for (a, b) in scores.iter().zip(&warm) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // a differently-configured problem must not be warmed
        let r = problem(&space, &set).restricted(1);
        assert_eq!(ck.warm_problem(&r), 0);
    }

    fn acc_problem<'a>(
        space: &'a SearchSpace,
        set: &'a WorkloadSet,
    ) -> JointProblem<'a> {
        JointProblem::with_backend(
            space,
            set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max),
        )
    }

    #[test]
    fn acc_memo_persists_and_warms_matching_scopes() {
        let dir = tmp("accmemo");
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let mut rng = Rng::seed_from(44);
        let p = acc_problem(&space, &set);
        let designs: Vec<crate::space::Design> =
            (0..6).map(|_| p.random_candidate(&mut rng)).collect();
        let scores = p.score_batch(&designs);
        assert!(p.acc_cache_len() > 0);
        {
            let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
            ck.absorb_problem(&p).unwrap();
        }
        assert!(
            dir.join("checkpoints/demo.acc.jsonl").exists(),
            "acc memo file must be written"
        );
        // a resumed checkpoint warms the acc cache alongside the eval memo
        let ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        let q = acc_problem(&space, &set);
        assert_eq!(ck.warm_problem(&q), p.cache_len());
        assert_eq!(q.acc_cache_len(), p.acc_cache_len());
        let warm = q.score_batch(&designs);
        assert_eq!(q.evals(), 0);
        for (a, b) in scores.iter().zip(&warm) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // a problem with a different acc scope (SRAM) imports nothing
        let sspace = SearchSpace::sram();
        let r = JointProblem::with_backend(
            &sspace,
            &set,
            EvalBackend::native(MemoryTech::Sram),
            Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max),
        );
        ck.warm_problem(&r);
        assert_eq!(r.acc_cache_len(), 0);
        // absorbing an unchanged problem appends nothing new
        let mut ck2 = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        let before = std::fs::metadata(dir.join("checkpoints/demo.acc.jsonl"))
            .unwrap()
            .len();
        ck2.warm_problem(&q);
        ck2.absorb_problem(&q).unwrap();
        let after = std::fs::metadata(dir.join("checkpoints/demo.acc.jsonl"))
            .unwrap()
            .len();
        assert_eq!(before, after);
    }

    #[test]
    fn acc_key_codec_roundtrips() {
        for k in [
            (0u16, 0u16, 0u16, 0u16),
            (4, 7, 2, 0),
            (512, 256, 4, 0),
            (4, 7, 2, 1),
            (512, 256, 4, 27),
        ] {
            assert_eq!(parse_acc_key(&acc_key_to_string(k)), Some(k));
        }
        // nominal keys keep the legacy three-component spelling ...
        assert_eq!(acc_key_to_string((4, 7, 2, 0)), "4,7,2");
        assert_eq!(acc_key_to_string((4, 7, 2, 3)), "4,7,2,3");
        // ... and legacy memo files parse as perturbation id 0
        assert_eq!(parse_acc_key("4,7,2"), Some((4, 7, 2, 0)));
        assert_eq!(parse_acc_key("1,2"), None);
        assert_eq!(parse_acc_key("1,2,3,4,5"), None);
        assert_eq!(parse_acc_key("a,b,c"), None);
    }

    #[test]
    fn robust_acc_memo_round_trips_with_scope_isolation() {
        use crate::robustness::RobustConfig;
        let dir = tmp("robustmemo");
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let rc = RobustConfig::from_flag("worst", 5, 1).unwrap();
        let p = acc_problem(&space, &set).with_robust(Some(rc.clone()));
        let mut rng = Rng::seed_from(45);
        let designs: Vec<Design> =
            (0..5).map(|_| p.random_candidate(&mut rng)).collect();
        let scores = p.score_batch(&designs);
        assert!(p.acc_cache_len() > 0);
        {
            let mut ck = Checkpoint::for_experiment(&dir, "demo", false).unwrap();
            ck.absorb_problem(&p).unwrap();
        }
        let ck = Checkpoint::for_experiment(&dir, "demo", true).unwrap();
        // same robust config warms everything, scores replay bit-identically
        let q = acc_problem(&space, &set).with_robust(Some(rc));
        ck.warm_problem(&q);
        assert_eq!(q.acc_cache_len(), p.acc_cache_len());
        let warm = q.score_batch(&designs);
        assert_eq!(q.evals(), 0);
        for (a, b) in scores.iter().zip(&warm) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // a different ensemble (or none) is a different scope: no import
        let other = acc_problem(&space, &set)
            .with_robust(Some(RobustConfig::from_flag("worst", 6, 1).unwrap()));
        ck.warm_problem(&other);
        assert_eq!(other.acc_cache_len(), 0);
        let nominal = acc_problem(&space, &set);
        ck.warm_problem(&nominal);
        assert_eq!(nominal.acc_cache_len(), 0);
    }
}
