//! Device-variation robustness study (the `robustness` experiment).
//!
//! Two questions the scalarized reproduction cannot answer:
//!
//! 1. **What does nominal-point optimization give up under device
//!    variation?** Two equal-budget four-phase GA searches on the cnn4 /
//!    RRAM family under the accuracy-aware EDAP objective: one at the
//!    nominal operating point, one under the robust aggregate
//!    (`--robust`, default `worst`) over a seeded corners-and-draws
//!    [`PerturbationEnsemble`]. Each chosen design is then cross-scored
//!    under *both* problems, giving the robust regret of the nominal
//!    design (how much worse its worst case is) and the nominal cost of
//!    the robust design (how much nominal headroom robustness buys away).
//!
//! 2. **What does an accuracy floor cost in EDAP?** Per memory
//!    technology (cnn4 on RRAM and on SRAM), three equal-budget NSGA-II
//!    metric-mode fronts: unconstrained, and with `--acc-floor`-style
//!    constraint-domination floors at `a0 + 0.5%` and `a0 + 1%`, where
//!    `a0` is the minimum nominal accuracy of the unconstrained front's
//!    minimum-EDAP corner. The reported curve is the corner-EDAP ratio
//!    against the unconstrained front — "the EDAP cost of a +1% accuracy
//!    floor". On SRAM the accuracy model is design-invariant (no analog
//!    noise), so any floor above the fixed baseline is infeasible by
//!    construction — the curve reports that as `inf` instead of hiding
//!    the row.
//!
//! Every search is a checkpoint cell (`--resume` replays); standalone
//! JSON artifacts land in `<out_dir>/robustness_cells/`
//! (`schemas/robustness_cell.schema.json`). Determinism: everything is a
//! pure function of (seed, config) — bit-identical across `--threads`,
//! `--workers` and kill/`--resume` (`rust/tests/robustness_determinism.rs`).
//!
//! [`PerturbationEnsemble`]: crate::robustness::PerturbationEnsemble

use super::checkpoint::{self, Checkpoint};
use super::common;
use super::pareto::{moo_result_from_json, moo_result_to_json};
use crate::accuracy;
use crate::coordinator::{ExpContext, JointProblem};
use crate::model::MemoryTech;
use crate::objective::{Aggregation, Objective, ObjectiveKind};
use crate::pareto::{MooMode, MooProblem, MooResult, MultiObjectiveOptimizer, Nsga2, Nsga2Config};
use crate::report::Report;
use crate::robustness::{Corner, RobustConfig};
use crate::search::{GaConfig, InitStrategy, Problem};
use crate::space::{Design, SearchSpace};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::{Context, Result};

/// Registry entry (see `experiments::REGISTRY`).
pub struct Robustness;

impl super::Experiment for Robustness {
    fn id(&self) -> &'static str {
        "robustness"
    }
    fn description(&self) -> &'static str {
        "Device-variation robustness: nominal-vs-robust designs and accuracy-floor cost"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Medium
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

/// The robust configuration this experiment studies: the user's
/// `--robust` mode when given, the worst-case corners-and-draws ensemble
/// otherwise (the study needs *a* robust objective even when the global
/// flag is off; the flag then only changes which aggregate is compared).
fn study_robust_config(ctx: &ExpContext) -> Result<RobustConfig> {
    Ok(match ctx.robust_config()? {
        Some(rc) => rc,
        None => RobustConfig::from_flag("worst", ctx.seed, ctx.robust_draws())?,
    })
}

/// NSGA-II sized by the context (mirrors the `pareto` experiment's
/// configuration so corner EDAPs are comparable across studies).
fn nsga_config(ctx: &ExpContext) -> Nsga2Config {
    let (p_h, p_e) = ctx.sampling();
    Nsga2Config {
        init: InitStrategy::HammingDiverse { p_h, p_e },
        cap: ctx.pareto_cap,
        screen_frac: ctx.screen_frac,
        ..Nsga2Config::paper(ctx.budget())
    }
}

/// Journal a [`MooResult`] as a checkpoint cell (same codec as `pareto`).
fn moo_cell(
    ckpt: &mut Checkpoint,
    key: &str,
    compute: impl FnOnce() -> MooResult,
) -> Result<MooResult> {
    let v = ckpt.cell(key, || Ok(moo_result_to_json(&compute())))?;
    moo_result_from_json(&v)
}

/// Index of the minimum finite scalar (first on ties); `None` when no
/// entry is finite (e.g. a floor nobody can reach).
fn argmin_scalar(scalars: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &s) in scalars.iter().enumerate() {
        if !s.is_finite() {
            continue;
        }
        match best {
            Some((_, b)) if s >= b => {}
            _ => best = Some((i, s)),
        }
    }
    best.map(|(i, _)| i)
}

/// Smallest accuracy across a workload set for one design at a
/// device-variation corner, through the public accuracy + robustness
/// models (the same per-layer quadrature the joint problem memoizes).
fn min_accuracy_at_corner(
    space: &SearchSpace,
    set: &WorkloadSet,
    mem: MemoryTech,
    d: &Design,
    corner: Corner,
) -> f64 {
    let raw = space.decode(d);
    let spec = corner.perturbation().apply(&accuracy::NoiseSpec::from_design(&raw, mem));
    let per_layer = accuracy::analytical_eps(&spec, 1);
    set.workloads
        .iter()
        .map(|w| {
            let eps = per_layer * (w.mapped_layers() as f64).sqrt();
            let (base, chance) = accuracy::baseline(&w.name);
            accuracy::accuracy_from_eps(eps, base, chance)
        })
        .fold(f64::INFINITY, f64::min)
}

/// One side of the nominal-vs-robust comparison, fully cross-scored.
struct GapSide {
    label: &'static str,
    design: Design,
    nominal_score: f64,
    robust_score: f64,
    min_nominal_acc: f64,
    min_high_corner_acc: f64,
}

/// One point of a floor-cost curve.
struct FloorPoint {
    floor: Option<f64>,
    corner_edap: f64,
    front_size: usize,
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let mut report = Report::new(
        "robustness",
        "Device variation: nominal-vs-robust designs and the EDAP cost of accuracy floors",
    );
    let cells_dir = ctx.out_dir.join("robustness_cells");
    // like pareto_fronts/: the floor values depend on the configuration,
    // so the directory always reflects exactly one run's cells
    if cells_dir.exists() {
        std::fs::remove_dir_all(&cells_dir)
            .with_context(|| format!("clearing {}", cells_dir.display()))?;
    }
    std::fs::create_dir_all(&cells_dir)
        .with_context(|| format!("creating {}", cells_dir.display()))?;

    // ---- part 1: nominal vs robust GA on cnn4 / RRAM ---------------------
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let objective = Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max);
    let rc = study_robust_config(ctx)?;
    let nominal_problem = ctx
        .problem(&space, &set, MemoryTech::Rram, objective)
        .with_robust(None);
    let robust_problem = ctx
        .problem(&space, &set, MemoryTech::Rram, objective)
        .with_robust(Some(rc.clone()));
    let cfg = GaConfig {
        top_k: ctx.top_k,
        ..common::four_phase(ctx)
    };

    ckpt.warm_problem(&nominal_problem);
    let nominal = common::ga_cell(
        ckpt,
        "robustness:cnn4:nominal",
        &nominal_problem,
        cfg.clone(),
        ctx.seed,
    )?;
    ckpt.absorb_problem(&nominal_problem)?;
    ckpt.warm_problem(&robust_problem);
    let robust = common::ga_cell(
        ckpt,
        "robustness:cnn4:robust",
        &robust_problem,
        cfg,
        ctx.seed,
    )?;
    ckpt.absorb_problem(&robust_problem)?;

    let side = |label: &'static str, r: &crate::search::OptResult| -> GapSide {
        let d = r.best.clone();
        GapSide {
            label,
            nominal_score: nominal_problem.score_batch(&[d.clone()])[0],
            robust_score: robust_problem.score_batch(&[d.clone()])[0],
            min_nominal_acc: nominal_problem
                .nominal_accuracies(&d)
                .iter()
                .copied()
                .fold(f64::INFINITY, f64::min),
            min_high_corner_acc: min_accuracy_at_corner(
                &space,
                &set,
                MemoryTech::Rram,
                &d,
                Corner::High,
            ),
            design: d,
        }
    };
    let sides = [side("nominal", &nominal), side("robust", &robust)];
    // how much worse the nominal design's worst case is than the robust
    // design's, and what the robust design pays at the nominal point
    let regret = sides[0].robust_score / sides[1].robust_score;
    let nominal_cost = sides[1].nominal_score / sides[0].nominal_score;

    let mut gap_table = Table::new(
        &format!(
            "nominal vs robust four-phase GA on cnn4/RRAM (accuracy-aware EDAP, \
             robust aggregate = {})",
            rc.descriptor()
        ),
        &[
            "design",
            "nominal score",
            "robust score",
            "min acc (nominal)",
            "min acc (high corner)",
        ],
    );
    for s in &sides {
        gap_table.row(vec![
            s.label.to_string(),
            common::s(s.nominal_score),
            common::s(s.robust_score),
            common::s(s.min_nominal_acc),
            common::s(s.min_high_corner_acc),
        ]);
    }
    report.table(gap_table);

    let side_json = |s: &GapSide| {
        Json::obj(vec![
            ("design", checkpoint::design_to_json(&s.design)),
            ("described", Json::Str(space.describe(&s.design))),
            ("nominal_score", Json::f64(s.nominal_score)),
            ("robust_score", Json::f64(s.robust_score)),
            ("min_nominal_accuracy", Json::f64(s.min_nominal_acc)),
            ("min_high_corner_accuracy", Json::f64(s.min_high_corner_acc)),
        ])
    };
    let gap_cell = Json::obj(vec![
        ("experiment", Json::Str("robustness".into())),
        ("kind", Json::Str("gap".into())),
        ("set", Json::Str("cnn4".into())),
        ("mem", Json::Str(MemoryTech::Rram.name().into())),
        ("robust", Json::Str(rc.descriptor())),
        ("seed", Json::Num(ctx.seed as f64)),
        ("nominal", side_json(&sides[0])),
        ("robust_design", side_json(&sides[1])),
        ("robust_regret", Json::f64(regret)),
        ("nominal_cost", Json::f64(nominal_cost)),
    ]);
    let gap_path = cells_dir.join("gap.json");
    crate::util::write_atomic(&gap_path, &(gap_cell.to_string() + "\n"))
        .with_context(|| format!("writing {}", gap_path.display()))?;

    // ---- part 2: accuracy-floor cost curves, RRAM vs SRAM ----------------
    let mut floor_table = Table::new(
        "EDAP cost of nominal-accuracy floors (NSGA-II metric fronts at equal \
         budget; corner = minimum-EDAP front point; floors sit 0.5% and 1% \
         above the unconstrained corner's minimum accuracy)",
        &["set", "mem", "floor", "corner EDAP", "vs unconstrained", "front"],
    );
    let sram_space = SearchSpace::sram();
    let legs: [(&str, &SearchSpace, MemoryTech, Aggregation); 2] = [
        ("rram", &space, MemoryTech::Rram, Aggregation::Max),
        ("sram", &sram_space, MemoryTech::Sram, Aggregation::Mean),
    ];
    for (li, (leg, leg_space, mem, agg)) in legs.iter().enumerate() {
        let problem = ctx.problem(leg_space, &set, *mem, Objective::new(ObjectiveKind::Edap, *agg));
        ckpt.warm_problem(&problem);
        let seed = ctx.seed.wrapping_add(li as u64 * 9973 + 1);
        let corner_of = |mr: &MooResult, problem: &JointProblem<'_>| {
            let designs: Vec<Design> = mr.front.iter().map(|(d, _)| d.clone()).collect();
            let scalars = problem.score_batch(&designs);
            argmin_scalar(&scalars).map(|i| (designs[i].clone(), scalars[i]))
        };

        // unconstrained reference front: its corner anchors the floors
        let base = moo_cell(ckpt, &format!("robustness:floor:{leg}:base"), || {
            let moo = MooProblem::new(&problem, MooMode::Metric);
            Nsga2::new(nsga_config(ctx)).run(&moo, &mut Rng::seed_from(seed))
        })?;
        let base_corner = corner_of(&base, &problem);
        let (a0, base_edap) = match &base_corner {
            Some((d, s)) => {
                let a = problem
                    .nominal_accuracies(d)
                    .iter()
                    .copied()
                    .fold(f64::INFINITY, f64::min);
                (a, *s)
            }
            None => (f64::NAN, f64::INFINITY),
        };

        let mut points = vec![FloorPoint {
            floor: None,
            corner_edap: base_edap,
            front_size: base.front.len(),
        }];
        if a0.is_finite() {
            for (fi, delta) in [0.005, 0.01].into_iter().enumerate() {
                let floor = (a0 + delta).min(0.9999);
                let mr = moo_cell(ckpt, &format!("robustness:floor:{leg}:f{fi}"), || {
                    let moo =
                        MooProblem::new(&problem, MooMode::Metric).with_acc_floor(Some(floor));
                    Nsga2::new(nsga_config(ctx)).run(&moo, &mut Rng::seed_from(seed))
                })?;
                let edap = corner_of(&mr, &problem).map(|(_, s)| s).unwrap_or(f64::INFINITY);
                points.push(FloorPoint {
                    floor: Some(floor),
                    corner_edap: edap,
                    front_size: mr.front.len(),
                });
            }
        }
        ckpt.absorb_problem(&problem)?;

        for p in &points {
            floor_table.row(vec![
                "cnn4".into(),
                mem.name().to_string(),
                p.floor.map(|f| common::s(f)).unwrap_or_else(|| "none".into()),
                common::s(p.corner_edap),
                common::s(p.corner_edap / base_edap),
                p.front_size.to_string(),
            ]);
        }
        let cell = Json::obj(vec![
            ("experiment", Json::Str("robustness".into())),
            ("kind", Json::Str("floor_curve".into())),
            ("set", Json::Str("cnn4".into())),
            ("mem", Json::Str(mem.name().into())),
            ("seed", Json::Num(ctx.seed as f64)),
            ("baseline_min_accuracy", Json::f64(a0)),
            (
                "points",
                Json::Arr(
                    points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                (
                                    "floor",
                                    match p.floor {
                                        Some(f) => Json::f64(f),
                                        None => Json::Null,
                                    },
                                ),
                                ("corner_edap", Json::f64(p.corner_edap)),
                                ("cost_vs_base", Json::f64(p.corner_edap / base_edap)),
                                ("front_size", Json::Num(p.front_size as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let path = cells_dir.join(format!("floor-{leg}.json"));
        crate::util::write_atomic(&path, &(cell.to_string() + "\n"))
            .with_context(|| format!("writing {}", path.display()))?;
    }
    report.table(floor_table);

    report.note(format!(
        "robust regret {} = the nominal design's robust (ensemble-aggregate) \
         score over the robust design's; nominal cost {} = the robust design's \
         nominal score over the nominal design's. Floors constrain the minimum \
         *nominal* accuracy across the cnn4 workloads via constraint-domination \
         (pareto::VectorObjective); on SRAM the accuracy model is \
         design-invariant, so any floor above the fixed baseline reports inf — \
         the accuracy floor is an RRAM design lever, not an SRAM one.",
        common::s(regret),
        common::s(nominal_cost),
    ));
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn quick_run_emits_gap_and_floor_cells() {
        let mut ctx = ExpContext::quick(83);
        ctx.out_dir = std::env::temp_dir().join("imcopt-robustness-test");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 2);
        assert_eq!(r.tables[0].rows.len(), 2, "nominal + robust rows");
        assert_eq!(r.tables[1].rows.len(), 6, "2 legs x 3 floor points");

        let gap = json::parse(
            &std::fs::read_to_string(ctx.out_dir.join("robustness_cells/gap.json")).unwrap(),
        )
        .unwrap();
        assert_eq!(gap.get("kind").and_then(|k| k.as_str()), Some("gap"));
        assert_eq!(
            gap.get("robust").and_then(|x| x.as_str()),
            Some(format!("worst@ens-s{}-k2", ctx.seed).as_str()),
            "quick mode draws 2 per corner"
        );
        for key in ["nominal", "robust_design"] {
            let s = gap.get(key).unwrap();
            let nom = s.get("nominal_score").and_then(|x| x.as_f64_lenient()).unwrap();
            let rob = s.get("robust_score").and_then(|x| x.as_f64_lenient()).unwrap();
            // a feasible design's robust worst case is never better than
            // its nominal score (perturbations only add noise)
            if nom.is_finite() && rob.is_finite() {
                assert!(rob >= nom * (1.0 - 1e-12), "{key}: {rob} < {nom}");
            }
        }

        let rram = json::parse(
            &std::fs::read_to_string(ctx.out_dir.join("robustness_cells/floor-rram.json"))
                .unwrap(),
        )
        .unwrap();
        assert_eq!(rram.get("kind").and_then(|k| k.as_str()), Some("floor_curve"));
        let points = rram.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(points.len(), 3, "base + two floors");
        assert_eq!(points[0].get("floor"), Some(&json::Json::Null));
        let a0 = rram
            .get("baseline_min_accuracy")
            .and_then(|x| x.as_f64_lenient())
            .unwrap();
        assert!(a0.is_finite() && a0 > 0.0 && a0 < 1.0, "{a0}");

        // SRAM: design-invariant accuracy, so every floor above the fixed
        // baseline is infeasible by construction
        let sram = json::parse(
            &std::fs::read_to_string(ctx.out_dir.join("robustness_cells/floor-sram.json"))
                .unwrap(),
        )
        .unwrap();
        let spoints = sram.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(spoints.len(), 3);
        for p in &spoints[1..] {
            let edap = p.get("corner_edap").and_then(|x| x.as_f64_lenient()).unwrap();
            assert!(edap.is_infinite(), "SRAM floored corner must be infeasible: {edap}");
        }
    }

    #[test]
    fn study_config_honors_the_flag_and_defaults_to_worst() {
        let mut ctx = ExpContext::quick(5);
        let rc = study_robust_config(&ctx).unwrap();
        assert_eq!(rc.descriptor(), "worst@ens-s5-k2");
        ctx.robust = Some("cvar0.5".into());
        let rc = study_robust_config(&ctx).unwrap();
        assert_eq!(rc.descriptor(), "cvar0.5@ens-s5-k2");
        ctx.robust = Some("nope".into());
        assert!(study_robust_config(&ctx).is_err());
    }
}
