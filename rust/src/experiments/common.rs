//! Shared experiment plumbing: GA configurations scaled by the context,
//! joint / separate / largest-workload search runners, checkpoint-aware
//! cell wrappers, and formatting.

use super::checkpoint::{self, Checkpoint};
use crate::coordinator::{ExpContext, JointProblem};
use crate::model::MemoryTech;
use crate::objective::Objective;
use crate::scenarios::{self, Portfolio, ScenarioSpec};
use crate::search::{GaConfig, GeneticAlgorithm, InitStrategy, OptResult, Optimizer};
use crate::space::SearchSpace;
use crate::util::fmt_sig;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workloads::WorkloadSet;
use anyhow::{Context, Result};
use std::path::Path;

/// The proposed 4-phase GA sized by the context (paper budget unless
/// `--quick`), with the context's surrogate screening fraction
/// (`--screen-frac`; 1.0 = exact loop).
pub fn four_phase(ctx: &ExpContext) -> GaConfig {
    let (p_h, p_e) = ctx.sampling();
    GaConfig {
        init: InitStrategy::HammingDiverse { p_h, p_e },
        screen_frac: ctx.screen_frac,
        ..GaConfig::four_phase(ctx.budget())
    }
}

/// Non-modified GA baseline \[44\].
pub fn classic(ctx: &ExpContext) -> GaConfig {
    GaConfig {
        screen_frac: ctx.screen_frac,
        ..GaConfig::classic(ctx.budget())
    }
}

/// Non-modified GA with the enhanced-sampling front-end.
pub fn classic_sampled(ctx: &ExpContext) -> GaConfig {
    let (p_h, p_e) = ctx.sampling();
    GaConfig {
        init: InitStrategy::HammingDiverse { p_h, p_e },
        screen_frac: ctx.screen_frac,
        ..GaConfig::classic(ctx.budget())
    }
}

/// Run one GA configuration on a problem with a derived seed.
pub fn run_ga(problem: &JointProblem<'_>, cfg: GaConfig, seed: u64) -> OptResult {
    GeneticAlgorithm::new(cfg).run(problem, &mut Rng::seed_from(seed))
}

/// Journal any optimizer run as a checkpoint cell: a journaled key replays
/// the stored [`OptResult`] without touching the evaluator; a miss runs
/// `compute`, journals and flushes. Keys must be unique within one
/// experiment (convention: `<id>:<scenario>:<unit>[:<seed>]`).
pub fn opt_cell(
    ckpt: &mut Checkpoint,
    key: &str,
    compute: impl FnOnce() -> OptResult,
) -> Result<OptResult> {
    crate::telemetry::set_cell_key(key, None);
    let v = ckpt.cell(key, || Ok(checkpoint::opt_result_to_json(&compute())))?;
    checkpoint::opt_result_from_json(&v)
}

/// [`opt_cell`] specialized to [`run_ga`], the unit of work most
/// experiments journal.
pub fn ga_cell(
    ckpt: &mut Checkpoint,
    key: &str,
    problem: &JointProblem<'_>,
    cfg: GaConfig,
    seed: u64,
) -> Result<OptResult> {
    crate::telemetry::set_cell_key(key, Some(seed));
    opt_cell(ckpt, key, || run_ga(problem, cfg, seed))
}

/// [`opt_cell`] through the shared cross-experiment namespace
/// ([`Checkpoint::shared_cell`]): the result is journaled under this
/// experiment's `key` *and* published under `shared_key`, so later
/// experiments of the same run replay it instead of recomputing a
/// bit-identical search. Only use for searches whose (problem, config,
/// seed) derivation is identical wherever `shared_key` is used — the
/// specialist bounds (`bound:<set>:<w>`) are the canonical case.
pub fn opt_shared_cell(
    ckpt: &mut Checkpoint,
    key: &str,
    shared_key: &str,
    compute: impl FnOnce() -> OptResult,
) -> Result<OptResult> {
    crate::telemetry::set_cell_key(key, None);
    let v = ckpt.shared_cell(key, shared_key, || {
        Ok(checkpoint::opt_result_to_json(&compute()))
    })?;
    checkpoint::opt_result_from_json(&v)
}

/// The scenario families an experiment should sweep: the user-defined
/// `--spec` family when given ([`ScenarioSpec::parse`] — canonical
/// names, ingested `.json`/`.onnx` files, or a `synth:` population),
/// the two paper families otherwise. Honored by `genmatrix_k`,
/// `transfer` and `pareto` (the `genmatrix` paper reproduction always
/// runs the paper families; `population` defaults to a synthetic family
/// instead, see `experiments::population`).
pub fn resolve_specs(ctx: &ExpContext) -> Result<Vec<ScenarioSpec>> {
    match &ctx.spec {
        Some(s) => Ok(vec![ScenarioSpec::parse(s)
            .with_context(|| format!("parsing --spec '{s}'"))?]),
        None => Ok(scenarios::paper_specs()),
    }
}

/// [`naive_largest_search`] as a checkpoint cell (the §IV-A baseline used
/// by fig3/fig5/fig10): largest workload + conventional random-init GA,
/// with the per-config eval memo persisted for warm resume. One
/// definition so the baseline cannot silently diverge between figures.
#[allow(clippy::too_many_arguments)]
pub fn naive_largest_cell(
    ckpt: &mut Checkpoint,
    key: &str,
    ctx: &ExpContext,
    space: &SearchSpace,
    set: &WorkloadSet,
    mem: MemoryTech,
    objective: Objective,
    seed: u64,
) -> Result<OptResult> {
    let li = largest_workload_index(set, mem);
    let problem = ctx.problem(space, set, mem, objective).restricted(li);
    ckpt.warm_problem(&problem);
    let r = ga_cell(ckpt, key, &problem, classic(ctx), seed)?;
    ckpt.absorb_problem(&problem)?;
    Ok(r)
}

/// The §IV-A baseline: "optimization for the maximum workload ... a naive
/// approach commonly used in hardware design" — the conventional flow:
/// single (largest) target workload AND the conventional random-init GA
/// \[44\]. The paper attributes the joint method's Fig. 3/Fig. 10 gains to
/// better exploration "within the same number of generations and
/// population size constraints", i.e. to exactly this search-quality gap;
/// see EXPERIMENTS.md for the interpretation note.
pub fn naive_largest_search(
    ctx: &ExpContext,
    space: &SearchSpace,
    set: &WorkloadSet,
    mem: MemoryTech,
    objective: Objective,
    seed: u64,
) -> OptResult {
    let li = largest_workload_index(set, mem);
    let problem = ctx.problem(space, set, mem, objective).restricted(li);
    run_ga(&problem, classic(ctx), seed)
}

/// Paper baseline: optimize only for the largest workload, then deploy on
/// everything (§IV-A). The "largest" criterion follows the paper: total
/// weights for weight-stationary RRAM, largest single layer for
/// weight-swapping SRAM (§IV-J).
pub fn largest_workload_index(set: &WorkloadSet, mem: MemoryTech) -> usize {
    match mem {
        MemoryTech::Rram => set.largest_by_total(),
        MemoryTech::Sram => set.largest_by_layer(),
    }
}

/// One deployed workload inside a [`PortfolioOutcome`]: the joint
/// design's EDAP on it, the specialist bound, and their ratio (the
/// generalization gap, `scenarios::gap`).
#[derive(Clone, Copy, Debug)]
pub struct DeployGap {
    /// Workload index into the scenario's set.
    pub workload: usize,
    /// EDAP of the jointly-optimized design on this workload.
    pub joint_edap: f64,
    /// EDAP of the separate-search specialist for this workload.
    pub bound_edap: f64,
    /// `joint_edap / bound_edap` (NaN when the bound is unusable).
    pub gap: f64,
}

/// Result of running one [`Portfolio`]: the joint search outcome plus
/// deploy-side gap scoring against the per-workload specialist bounds.
pub struct PortfolioOutcome {
    /// The joint search on the portfolio's train set.
    pub joint: OptResult,
    /// The joint design's per-workload EDAP across the *full* set.
    pub joint_scores: Vec<f64>,
    /// One gap record per deploy workload (portfolio order).
    pub deploy: Vec<DeployGap>,
    /// Aggregates over the deploy gaps.
    pub summary: scenarios::GapSummary,
}

/// Cross-experiment shared-cell key for a jointly-optimized design: the
/// (problem, GA config, seed) derivation is fully determined by the
/// scenario, the train subset and the seed, so any two experiments that
/// arrive at the same `(spec, train, seed)` triple would run a
/// bit-identical search. Publishing the joint under this key lets
/// `genmatrix` replay `genmatrix_k`'s `k = 1` singleton-deploy cells (and
/// vice versa) instead of recomputing them — see `shares_joints` on
/// [`portfolio_cell`].
pub fn joint_shared_key(spec: &ScenarioSpec, train: &[usize], seed: u64) -> String {
    let train_tag = train
        .iter()
        .map(|i| i.to_string())
        .collect::<Vec<_>>()
        .join("+");
    format!("joint:{}:{train_tag}:{seed}", spec.name)
}

/// Run one portfolio through the checkpoint: a journaled joint search on
/// the train subset (key `<exp>:<set>:<portfolio>:joint`, seeded by
/// [`Portfolio::joint_seed`]), then dense deploy-side scoring of the
/// chosen design against the memoized per-workload bounds
/// ([`separate_bound_cell`]). The gap arithmetic matches `genmatrix`
/// exactly, so a `k = 1` hold-out portfolio reproduces the `genmatrix`
/// cell for that workload bit for bit.
///
/// With `shares_joints` the joint search is additionally published under
/// [`joint_shared_key`] so other experiments of the same run can replay
/// it ([`Checkpoint::shared_cell`]). Opt-in per caller: `genmatrix_k`
/// shares (its `k = 1` slice is provably identical to `genmatrix`'s
/// joints); `transfer` does not (its cells must stay independently
/// recomputable after a journal wipe).
pub fn portfolio_cell(
    ckpt: &mut Checkpoint,
    exp_id: &str,
    ctx: &ExpContext,
    spec: &ScenarioSpec,
    p: &Portfolio,
    shares_joints: bool,
) -> Result<PortfolioOutcome> {
    let joint_problem = ctx.spec_problem(spec).restricted_to(p.train.clone());
    ckpt.warm_problem(&joint_problem);
    let cfg = GaConfig {
        top_k: ctx.top_k,
        ..four_phase(ctx)
    };
    let key = format!("{exp_id}:{}:{}:joint", spec.name, p.id);
    let seed = p.joint_seed(ctx.seed);
    let joint = if shares_joints {
        opt_shared_cell(
            ckpt,
            &key,
            &joint_shared_key(spec, &p.train, seed),
            || run_ga(&joint_problem, cfg, seed),
        )?
    } else {
        ga_cell(ckpt, &key, &joint_problem, cfg, seed)?
    };
    ckpt.absorb_problem(&joint_problem)?;
    let joint_scores = per_workload_scores(&joint_problem, &joint.best, &Objective::edap());
    let mut deploy = Vec::with_capacity(p.deploy.len());
    for &wi in &p.deploy {
        let bound = separate_bound_cell(ckpt, exp_id, ctx, spec, wi)?;
        deploy.push(DeployGap {
            workload: wi,
            joint_edap: joint_scores[wi],
            bound_edap: bound,
            gap: scenarios::gap(joint_scores[wi], bound),
        });
    }
    let gaps: Vec<f64> = deploy.iter().map(|d| d.gap).collect();
    Ok(PortfolioOutcome {
        joint,
        joint_scores,
        deploy,
        summary: scenarios::summarize_gaps(&gaps),
    })
}

/// The separate-search (specialist) bound for one workload: the full
/// optimizer result, journaled once per experiment under
/// `<exp>:<set>:bound:<wi>` and *shared across experiments* through the
/// `bound:<set>:<wi>` namespace ([`Checkpoint::shared_cell`]) — the
/// (problem, GA config, [`scenarios::bound_seed`]) derivation is
/// identical in `genmatrix`, `genmatrix_k`, `transfer` and `pareto`, so
/// one run of `imcopt run --all` computes each bound exactly once.
pub fn separate_bound_result(
    ckpt: &mut Checkpoint,
    exp_id: &str,
    ctx: &ExpContext,
    spec: &ScenarioSpec,
    wi: usize,
) -> Result<(OptResult, f64)> {
    let sep_problem = ctx.spec_problem(spec).restricted(wi);
    ckpt.warm_problem(&sep_problem);
    let sep = opt_shared_cell(
        ckpt,
        &format!("{exp_id}:{}:bound:{wi}", spec.name),
        &format!("bound:{}:{wi}", spec.name),
        || run_ga(&sep_problem, four_phase(ctx), scenarios::bound_seed(ctx.seed, wi)),
    )?;
    ckpt.absorb_problem(&sep_problem)?;
    let bound = per_workload_scores(&sep_problem, &sep.best, &Objective::edap())[wi];
    Ok((sep, bound))
}

/// [`separate_bound_result`] reduced to the bound itself: the
/// specialist's EDAP on its own workload (the denominator of every
/// deploy-side gap).
pub fn separate_bound_cell(
    ckpt: &mut Checkpoint,
    exp_id: &str,
    ctx: &ExpContext,
    spec: &ScenarioSpec,
    wi: usize,
) -> Result<f64> {
    Ok(separate_bound_result(ckpt, exp_id, ctx, spec, wi)?.1)
}

/// Write one portfolio's standalone JSON cell artifact (shape pinned by
/// `schemas/portfolio_cell.schema.json`; rewritten even on resume so the
/// cell directory is complete after any run).
pub fn write_portfolio_cell(
    path: &Path,
    exp_id: &str,
    spec: &ScenarioSpec,
    p: &Portfolio,
    seed: u64,
    out: &PortfolioOutcome,
) -> Result<()> {
    let names = |indices: &[usize]| {
        Json::Arr(
            Portfolio::names(indices, &spec.set)
                .into_iter()
                .map(|n| Json::Str(n.to_string()))
                .collect(),
        )
    };
    let cell = Json::obj(vec![
        ("experiment", Json::Str(exp_id.to_string())),
        (
            "portfolio",
            Json::obj(vec![
                ("id", Json::Str(p.id.clone())),
                ("set", Json::Str(spec.name.to_string())),
                ("mem", Json::Str(spec.mem.name().to_string())),
                ("aggregation", Json::Str(spec.agg.name().to_string())),
                ("k", Json::Num(p.k() as f64)),
                ("train", names(&p.train)),
                ("deploy", names(&p.deploy)),
            ]),
        ),
        ("seed", Json::Num(seed as f64)),
        (
            "joint",
            Json::obj(vec![
                ("design", checkpoint::design_to_json(&out.joint.best)),
                ("described", Json::Str(spec.space.describe(&out.joint.best))),
                ("joint_score", Json::f64(out.joint.best_score)),
            ]),
        ),
        (
            "deploy_gaps",
            Json::Arr(
                out.deploy
                    .iter()
                    .map(|d| {
                        Json::obj(vec![
                            (
                                "workload",
                                Json::Str(spec.set.workloads[d.workload].name.to_string()),
                            ),
                            ("in_train", Json::Bool(p.train.contains(&d.workload))),
                            ("edap_joint", Json::f64(d.joint_edap)),
                            ("edap_bound", Json::f64(d.bound_edap)),
                            ("gap", Json::f64(d.gap)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "summary",
            Json::obj(vec![
                ("mean_gap", Json::f64(out.summary.mean)),
                ("geo_mean_gap", Json::f64(out.summary.geo_mean)),
                ("worst_gap", Json::f64(out.summary.worst)),
                ("finite_gaps", Json::Num(out.summary.finite as f64)),
                ("infeasible_rate", Json::f64(infeasible_rate(out))),
            ]),
        ),
        (
            "top",
            Json::Arr(
                out.joint
                    .top
                    .iter()
                    .map(|(d, s)| {
                        Json::obj(vec![
                            ("design", checkpoint::design_to_json(d)),
                            ("score", Json::f64(*s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    // atomic: concurrent orchestrator workers may emit the same cell
    crate::util::write_atomic(path, &(cell.to_string() + "\n"))
        .with_context(|| format!("writing portfolio cell {}", path.display()))
}

/// Fraction of a portfolio's deploy workloads whose gap is non-finite
/// (infeasible deployment or unusable bound). 0 for an empty deploy set.
/// Lets capacity-limited rows (e.g. gpt2-medium on RRAM) stay in the
/// table as a reported degradation instead of being excluded.
pub fn infeasible_rate(out: &PortfolioOutcome) -> f64 {
    if out.deploy.is_empty() {
        return 0.0;
    }
    1.0 - out.summary.finite as f64 / out.deploy.len() as f64
}

/// Per-workload single-workload scores of a chosen design (Fig. 3/5
/// reporting): `E_wi · L_wi · A`-style under the given objective.
pub fn per_workload_scores(
    problem: &JointProblem<'_>,
    design: &crate::space::Design,
    objective: &Objective,
) -> Vec<f64> {
    let raw = problem.space.decode(design);
    problem
        .metrics_all_workloads(design)
        .iter()
        .map(|m| objective.single_workload_score(m, raw[crate::space::idx::TECH_NM]))
        .collect()
}

/// Format a score/gap column. Non-finite values keep their meaning:
/// `inf` = infeasible deployment, `nan` = no usable bound to compare
/// against, `-inf` = an empty aggregate (e.g. a worst-gap over zero
/// finite gaps) — docs/scenarios.md documents the reading.
pub fn s(x: f64) -> String {
    if x.is_finite() {
        fmt_sig(x, 4)
    } else if x.is_nan() {
        "nan".into()
    } else if x > 0.0 {
        "inf".into()
    } else {
        "-inf".into()
    }
}

/// Percentage reduction of `b` relative to `a` (positive = b better).
pub fn reduction_pct(a: f64, b: f64) -> f64 {
    if a <= 0.0 || !a.is_finite() || !b.is_finite() {
        return f64::NAN;
    }
    (1.0 - b / a) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_math() {
        assert!((reduction_pct(10.0, 2.5) - 75.0).abs() < 1e-12);
        assert!(reduction_pct(0.0, 1.0).is_nan());
        assert!(reduction_pct(f64::INFINITY, 1.0).is_nan());
    }

    #[test]
    fn largest_criterion_differs_by_mem() {
        let set = WorkloadSet::all9();
        // RRAM: total weights -> gpt2; SRAM: largest layer -> vgg16
        assert_eq!(
            set.workloads[largest_workload_index(&set, MemoryTech::Rram)].name,
            "gpt2-medium"
        );
        assert_eq!(
            set.workloads[largest_workload_index(&set, MemoryTech::Sram)].name,
            "vgg16"
        );
    }

    #[test]
    fn quick_configs_shrink_with_context() {
        let ctx = ExpContext::quick(0);
        let cfg = four_phase(&ctx);
        assert!(cfg.budget.pop <= 16);
        match cfg.init {
            InitStrategy::HammingDiverse { p_h, .. } => assert!(p_h <= 100),
            _ => panic!("expected sampling init"),
        }
    }
}
