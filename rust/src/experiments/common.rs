//! Shared experiment plumbing: GA configurations scaled by the context,
//! joint / separate / largest-workload search runners, checkpoint-aware
//! cell wrappers, and formatting.

use super::checkpoint::{self, Checkpoint};
use crate::coordinator::{ExpContext, JointProblem};
use crate::model::MemoryTech;
use crate::objective::Objective;
use crate::search::{GaConfig, GeneticAlgorithm, InitStrategy, OptResult, Optimizer};
use crate::space::SearchSpace;
use crate::util::fmt_sig;
use crate::util::rng::Rng;
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// The proposed 4-phase GA sized by the context (paper budget unless
/// `--quick`).
pub fn four_phase(ctx: &ExpContext) -> GaConfig {
    let (p_h, p_e) = ctx.sampling();
    GaConfig {
        init: InitStrategy::HammingDiverse { p_h, p_e },
        ..GaConfig::four_phase(ctx.budget())
    }
}

/// Non-modified GA baseline \[44\].
pub fn classic(ctx: &ExpContext) -> GaConfig {
    GaConfig::classic(ctx.budget())
}

/// Non-modified GA with the enhanced-sampling front-end.
pub fn classic_sampled(ctx: &ExpContext) -> GaConfig {
    let (p_h, p_e) = ctx.sampling();
    GaConfig {
        init: InitStrategy::HammingDiverse { p_h, p_e },
        ..GaConfig::classic(ctx.budget())
    }
}

/// Run one GA configuration on a problem with a derived seed.
pub fn run_ga(problem: &JointProblem<'_>, cfg: GaConfig, seed: u64) -> OptResult {
    GeneticAlgorithm::new(cfg).run(problem, &mut Rng::seed_from(seed))
}

/// Journal any optimizer run as a checkpoint cell: a journaled key replays
/// the stored [`OptResult`] without touching the evaluator; a miss runs
/// `compute`, journals and flushes. Keys must be unique within one
/// experiment (convention: `<id>:<scenario>:<unit>[:<seed>]`).
pub fn opt_cell(
    ckpt: &mut Checkpoint,
    key: &str,
    compute: impl FnOnce() -> OptResult,
) -> Result<OptResult> {
    let v = ckpt.cell(key, || Ok(checkpoint::opt_result_to_json(&compute())))?;
    checkpoint::opt_result_from_json(&v)
}

/// [`opt_cell`] specialized to [`run_ga`], the unit of work most
/// experiments journal.
pub fn ga_cell(
    ckpt: &mut Checkpoint,
    key: &str,
    problem: &JointProblem<'_>,
    cfg: GaConfig,
    seed: u64,
) -> Result<OptResult> {
    opt_cell(ckpt, key, || run_ga(problem, cfg, seed))
}

/// [`naive_largest_search`] as a checkpoint cell (the §IV-A baseline used
/// by fig3/fig5/fig10): largest workload + conventional random-init GA,
/// with the per-config eval memo persisted for warm resume. One
/// definition so the baseline cannot silently diverge between figures.
#[allow(clippy::too_many_arguments)]
pub fn naive_largest_cell(
    ckpt: &mut Checkpoint,
    key: &str,
    ctx: &ExpContext,
    space: &SearchSpace,
    set: &WorkloadSet,
    mem: MemoryTech,
    objective: Objective,
    seed: u64,
) -> Result<OptResult> {
    let li = largest_workload_index(set, mem);
    let problem = ctx.problem(space, set, mem, objective).restricted(li);
    ckpt.warm_problem(&problem);
    let r = ga_cell(ckpt, key, &problem, classic(ctx), seed)?;
    ckpt.absorb_problem(&problem)?;
    Ok(r)
}

/// The §IV-A baseline: "optimization for the maximum workload ... a naive
/// approach commonly used in hardware design" — the conventional flow:
/// single (largest) target workload AND the conventional random-init GA
/// \[44\]. The paper attributes the joint method's Fig. 3/Fig. 10 gains to
/// better exploration "within the same number of generations and
/// population size constraints", i.e. to exactly this search-quality gap;
/// see EXPERIMENTS.md for the interpretation note.
pub fn naive_largest_search(
    ctx: &ExpContext,
    space: &SearchSpace,
    set: &WorkloadSet,
    mem: MemoryTech,
    objective: Objective,
    seed: u64,
) -> OptResult {
    let li = largest_workload_index(set, mem);
    let problem = ctx.problem(space, set, mem, objective).restricted(li);
    run_ga(&problem, classic(ctx), seed)
}

/// Paper baseline: optimize only for the largest workload, then deploy on
/// everything (§IV-A). The "largest" criterion follows the paper: total
/// weights for weight-stationary RRAM, largest single layer for
/// weight-swapping SRAM (§IV-J).
pub fn largest_workload_index(set: &WorkloadSet, mem: MemoryTech) -> usize {
    match mem {
        MemoryTech::Rram => set.largest_by_total(),
        MemoryTech::Sram => set.largest_by_layer(),
    }
}

/// Per-workload single-workload scores of a chosen design (Fig. 3/5
/// reporting): `E_wi · L_wi · A`-style under the given objective.
pub fn per_workload_scores(
    problem: &JointProblem<'_>,
    design: &crate::space::Design,
    objective: &Objective,
) -> Vec<f64> {
    let raw = problem.space.decode(design);
    problem
        .metrics_all_workloads(design)
        .iter()
        .map(|m| objective.single_workload_score(m, raw[crate::space::idx::TECH_NM]))
        .collect()
}

/// Format a score column.
pub fn s(x: f64) -> String {
    if x.is_finite() {
        fmt_sig(x, 4)
    } else {
        "inf".into()
    }
}

/// Percentage reduction of `b` relative to `a` (positive = b better).
pub fn reduction_pct(a: f64, b: f64) -> f64 {
    if a <= 0.0 || !a.is_finite() || !b.is_finite() {
        return f64::NAN;
    }
    (1.0 - b / a) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_math() {
        assert!((reduction_pct(10.0, 2.5) - 75.0).abs() < 1e-12);
        assert!(reduction_pct(0.0, 1.0).is_nan());
        assert!(reduction_pct(f64::INFINITY, 1.0).is_nan());
    }

    #[test]
    fn largest_criterion_differs_by_mem() {
        let set = WorkloadSet::all9();
        // RRAM: total weights -> gpt2; SRAM: largest layer -> vgg16
        assert_eq!(
            set.workloads[largest_workload_index(&set, MemoryTech::Rram)].name,
            "gpt2-medium"
        );
        assert_eq!(
            set.workloads[largest_workload_index(&set, MemoryTech::Sram)].name,
            "vgg16"
        );
    }

    #[test]
    fn quick_configs_shrink_with_context() {
        let ctx = ExpContext::quick(0);
        let cfg = four_phase(&ctx);
        assert!(cfg.budget.pop <= 16);
        match cfg.init {
            InitStrategy::HammingDiverse { p_h, .. } => assert!(p_h <= 100),
            _ => panic!("expected sampling init"),
        }
    }
}
