//! Hold-one-out generalization matrix — the paper's core claim (a single
//! jointly-optimized IMC design serves many networks with
//! near-specialized EDAP) as an explicit scenario sweep.
//!
//! For each workload `w` of a set, a design is jointly optimized on the
//! other N−1 workloads and deployed on `w`; its EDAP on `w` is compared
//! against the separate-search bound (a design optimized for `w` alone,
//! the Fig. 5 baseline). The ratio — the *generalization gap* — is 1.0
//! when the joint design matches the specialist on a network it never saw
//! during the search.
//!
//! Sets follow the paper's setups: `cnn4` on weight-stationary RRAM
//! (Max-aggregated EDAP) and `all9` on weight-swapping SRAM with Mean
//! aggregation (§IV-J, as in Fig. 10, so GPT-2 Medium does not dominate).
//!
//! Every (set, held-out) cell journals its two searches through the
//! checkpoint (resume skips completed cells; the per-config eval memo is
//! persisted for warm re-runs) and emits a standalone JSON artifact under
//! `<out_dir>/genmatrix_cells/<set>-<workload>.json` with the top-k
//! designs (`--topk`, default 5).

use super::checkpoint::{self, Checkpoint};
use super::common;
use crate::coordinator::ExpContext;
use crate::objective::Objective;
use crate::report::Report;
use crate::scenarios;
use crate::search::GaConfig;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::table::Table;
use anyhow::{Context, Result};

/// Registry entry (see `experiments::REGISTRY`).
pub struct GenMatrix;

impl super::Experiment for GenMatrix {
    fn id(&self) -> &'static str {
        "genmatrix"
    }
    fn description(&self) -> &'static str {
        "Hold-one-out generalization matrix: EDAP gap vs separate-search bound"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Heavy
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let edap = Objective::edap();
    let mut report = Report::new(
        "genmatrix",
        "Hold-one-out generalization: joint-on-rest vs separate-search EDAP",
    );
    let cells_dir = ctx.out_dir.join("genmatrix_cells");
    std::fs::create_dir_all(&cells_dir)
        .with_context(|| format!("creating {}", cells_dir.display()))?;

    // the two scenario families are single-sourced with genmatrix_k and
    // transfer (scenarios::paper_specs) so the sweeps cannot drift apart
    for spec in scenarios::paper_specs() {
        let (set_name, set, mem, space, agg) =
            (spec.name.as_str(), &spec.set, spec.mem, &spec.space, spec.agg);
        let objective = spec.objective();
        let mut t = Table::new(
            &format!(
                "{set_name} on {} — EDAP on the held-out workload (mJ·ms·mm²)",
                mem.name()
            ),
            &[
                "held-out",
                "joint on rest",
                "separate bound",
                "gap x",
                "topk spread",
            ],
        );
        let mut gaps: Vec<f64> = Vec::new();
        for wi in 0..set.len() {
            let held = set.workloads[wi].name.as_str();
            let train: Vec<usize> = (0..set.len()).filter(|&j| j != wi).collect();

            // joint search on the N−1 training workloads, published in the
            // shared cross-experiment namespace: genmatrix_k's k=1
            // singleton-deploy portfolios derive the same (problem, config,
            // seed) triple, so within one `run --all` sweep whichever of the
            // two experiments runs first computes the joint and the other
            // replays it (see `common::joint_shared_key`)
            let joint_problem = ctx
                .problem(space, set, mem, objective)
                .restricted_to(train.clone());
            ckpt.warm_problem(&joint_problem);
            let cfg = GaConfig {
                top_k: ctx.top_k,
                ..common::four_phase(ctx)
            };
            let seed = ctx.seed.wrapping_add(wi as u64 * 7919);
            let joint = common::opt_shared_cell(
                ckpt,
                &format!("genmatrix:{set_name}:{wi}:joint"),
                &common::joint_shared_key(&spec, &train, seed),
                || common::run_ga(&joint_problem, cfg, seed),
            )?;
            ckpt.absorb_problem(&joint_problem)?;

            // the specialist bound: separate search on the held-out
            // workload ([`scenarios::bound_seed`]-salted RNG stream, as in
            // fig5's strategy runs), journaled through the shared
            // cross-experiment `bound:<set>:<w>` namespace so the
            // portfolio experiments replay it instead of recomputing
            let (sep, bound) =
                common::separate_bound_result(ckpt, "genmatrix", ctx, &spec, wi)?;

            // per-workload EDAP of the joint design on the held-out workload
            let joint_scores =
                common::per_workload_scores(&joint_problem, &joint.best, &edap);
            let joint_held = joint_scores[wi];
            let gap = scenarios::gap(joint_held, bound);
            if gap.is_finite() {
                gaps.push(gap);
            }
            let spread = joint.spread();

            t.row(vec![
                held.into(),
                common::s(joint_held),
                common::s(bound),
                common::s(gap),
                format!("{spread:.3}"),
            ]);

            // standalone machine-readable cell artifact (rewritten even on
            // resume so the directory is complete after any run)
            let cell = Json::obj(vec![
                ("experiment", Json::Str("genmatrix".into())),
                ("set", Json::Str(set_name.into())),
                ("mem", Json::Str(mem.name().into())),
                ("aggregation", Json::Str(agg.name().into())),
                ("held_out", Json::Str(held.into())),
                (
                    "train",
                    Json::Arr(
                        train
                            .iter()
                            .map(|&j| Json::Str(set.workloads[j].name.clone()))
                            .collect(),
                    ),
                ),
                ("seed", Json::Num(ctx.seed as f64)),
                (
                    "joint",
                    Json::obj(vec![
                        ("design", checkpoint::design_to_json(&joint.best)),
                        ("described", Json::Str(space.describe(&joint.best))),
                        ("edap_heldout", Json::f64(joint_held)),
                        ("joint_score", Json::f64(joint.best_score)),
                    ]),
                ),
                (
                    "separate_bound",
                    Json::obj(vec![
                        ("design", checkpoint::design_to_json(&sep.best)),
                        ("edap", Json::f64(bound)),
                    ]),
                ),
                ("gap", Json::f64(gap)),
                (
                    "top",
                    Json::Arr(
                        joint
                            .top
                            .iter()
                            .map(|(d, s)| {
                                Json::obj(vec![
                                    ("design", checkpoint::design_to_json(d)),
                                    ("score", Json::f64(*s)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]);
            // atomic: concurrent orchestrator workers may emit the same cell
            crate::util::write_atomic(
                &cells_dir.join(format!("{set_name}-{held}.json")),
                &(cell.to_string() + "\n"),
            )
            .with_context(|| format!("writing genmatrix cell {set_name}-{held}"))?;
        }
        report.table(t);
        report.note(format!(
            "{set_name}/{}: geo-mean hold-one-out gap {:.3}x over {} workloads \
             (1.0 = generalizes as well as the specialist; paper: near-specialized \
             EDAP from one shared design)",
            mem.name(),
            stats::geo_mean(&gaps),
            set.len()
        ));
    }
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use crate::workloads::WorkloadSet;

    #[test]
    fn genmatrix_quick_emits_cells_for_both_sets() {
        let mut ctx = ExpContext::quick(47);
        ctx.out_dir = std::env::temp_dir().join("imcopt-genmatrix-test");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 2);
        assert_eq!(r.tables[0].rows.len(), 4);
        assert_eq!(r.tables[1].rows.len(), 9);
        // every cell artifact exists, parses, and carries the gap
        for (set_name, set) in [
            ("cnn4", WorkloadSet::cnn4()),
            ("all9", WorkloadSet::all9()),
        ] {
            for w in &set.workloads {
                let path = ctx
                    .out_dir
                    .join("genmatrix_cells")
                    .join(format!("{set_name}-{}.json", w.name));
                let text = std::fs::read_to_string(&path)
                    .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                let v = json::parse(&text).unwrap();
                assert_eq!(v.get("held_out").unwrap().as_str(), Some(w.name.as_str()));
                assert!(v.get("gap").unwrap().as_f64_lenient().is_some());
                let top = v.get("top").unwrap().as_arr().unwrap();
                assert!(!top.is_empty() && top.len() <= ctx.top_k);
                assert_eq!(
                    v.get("train").unwrap().as_arr().unwrap().len(),
                    set.len() - 1
                );
            }
        }
    }
}
