//! Fig. 10 (§IV-J): scalability and generalization — joint co-optimization
//! over the expanded 9-workload set (CNNs + DenseNet201, ResNet50, ViT,
//! MobileBERT, GPT-2 Medium) on SRAM weight-swapping hardware at 32 nm.
//!
//! As in the paper, the objective switches to **mean** energy/latency
//! aggregation so GPT-2 Medium does not dominate, and the "largest
//! workload" is defined by the largest single layer (VGG16's fc6, which
//! exceeds GPT-2's LM head). Headline claim: up to 95.5 % EDAP reduction
//! vs largest-workload optimization.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::{Aggregation, Objective, ObjectiveKind};
use crate::report::Report;
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Fig10;

impl super::Experiment for Fig10 {
    fn id(&self) -> &'static str {
        "fig10"
    }
    fn description(&self) -> &'static str {
        "9-workload scalability on SRAM weight-swapping hardware"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Medium
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::all9();
    let space = crate::space::SearchSpace::sram();
    // mean aggregation (§IV-J)
    let objective = Objective::new(ObjectiveKind::Edap, Aggregation::Mean);
    let edap = Objective::edap();
    let mut report = Report::new(
        "fig10",
        "9-workload scalability on SRAM weight-swapping hardware (mean aggregation)",
    );

    let li = common::largest_workload_index(&set, MemoryTech::Sram);
    assert_eq!(set.workloads[li].name, "vgg16");

    let problem = ctx.problem(&space, &set, MemoryTech::Sram, objective);
    let joint = common::ga_cell(
        ckpt,
        "fig10:joint",
        &problem,
        common::four_phase(ctx),
        ctx.seed,
    )?;
    let joint_time = joint.wall;
    let largest = common::naive_largest_cell(
        ckpt,
        "fig10:largest",
        ctx,
        &space,
        &set,
        MemoryTech::Sram,
        objective,
        ctx.seed,
    )?;

    let joint_scores = common::per_workload_scores(&problem, &joint.best, &edap);
    let largest_scores = common::per_workload_scores(&problem, &largest.best, &edap);

    let mut t = Table::new(
        "per-workload EDAP (mJ·ms·mm²) of top-1 designs",
        &["workload", "largest-workload opt", "joint opt (mean agg)", "reduction %"],
    );
    let mut max_red = f64::NEG_INFINITY;
    let mut wins = 0;
    for (i, w) in set.workloads.iter().enumerate() {
        let red = common::reduction_pct(largest_scores[i], joint_scores[i]);
        if joint_scores[i] <= largest_scores[i] * 1.001 {
            wins += 1;
        }
        max_red = max_red.max(red);
        t.row(vec![
            w.name.clone(),
            common::s(largest_scores[i]),
            common::s(joint_scores[i]),
            format!("{red:.1}"),
        ]);
    }
    report.table(t);
    report.note(format!(
        "joint wins/ties on {wins}/{} workloads; max per-workload EDAP reduction \
         {max_red:.1}% (paper: up to 95.5%)",
        set.len()
    ));
    report.note(format!(
        "joint design: {} | search wall {} | evals {}",
        space.describe(&joint.best),
        ctx.fmt_wall(joint_time),
        joint.evals
    ));
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_quick_covers_nine_workloads() {
        let ctx = ExpContext::quick(43);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables[0].rows.len(), 9);
        let names: Vec<&str> = r.tables[0].rows.iter().map(|x| x[0].as_str()).collect();
        assert!(names.contains(&"gpt2-medium"));
        assert!(names.contains(&"mobilebert"));
    }
}
