//! Fig. 3: EDAP of the top-1 design from joint optimization vs.
//! optimization for the largest workload only, for RRAM- and SRAM-based
//! hardware across the four CNN workloads. Headline claim: joint search
//! reduces EDAP by up to 76.2 % on the 4-workload set (§V-A).

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::Objective;
use crate::report::Report;
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Fig3;

impl super::Experiment for Fig3 {
    fn id(&self) -> &'static str {
        "fig3"
    }
    fn description(&self) -> &'static str {
        "EDAP of joint vs largest-workload optimization (RRAM & SRAM, 4 CNNs)"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Light
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let objective = Objective::edap();
    let mut report = Report::new(
        "fig3",
        "EDAP: joint co-optimization vs largest-workload optimization (RRAM & SRAM)",
    );

    for (mem, space) in [
        (MemoryTech::Rram, crate::space::SearchSpace::rram()),
        (MemoryTech::Sram, crate::space::SearchSpace::sram()),
    ] {
        // joint search with the proposed 4-phase GA
        let joint_problem = ctx.problem(&space, &set, mem, objective);
        let joint = common::ga_cell(
            ckpt,
            &format!("fig3:{}:joint", mem.name()),
            &joint_problem,
            common::four_phase(ctx),
            ctx.seed,
        )?;

        // the naive baseline of §IV-A: largest workload (VGG16 here) with
        // the conventional random-init GA
        let largest = common::naive_largest_cell(
            ckpt,
            &format!("fig3:{}:largest", mem.name()),
            ctx,
            &space,
            &set,
            mem,
            objective,
            ctx.seed,
        )?;

        let joint_scores =
            common::per_workload_scores(&joint_problem, &joint.best, &objective);
        let largest_scores =
            common::per_workload_scores(&joint_problem, &largest.best, &objective);

        let mut t = Table::new(
            &format!("{} — per-workload EDAP (mJ·ms·mm²) of top-1 designs", mem.name()),
            &["workload", "largest-workload opt", "joint opt", "reduction %"],
        );
        let mut max_red = f64::NEG_INFINITY;
        for (i, w) in set.workloads.iter().enumerate() {
            let red = common::reduction_pct(largest_scores[i], joint_scores[i]);
            max_red = max_red.max(red);
            t.row(vec![
                w.name.clone(),
                common::s(largest_scores[i]),
                common::s(joint_scores[i]),
                format!("{red:.1}"),
            ]);
        }
        report.table(t);
        report.note(format!(
            "{}: joint design {} | largest-workload design {} | max per-workload EDAP \
             reduction {:.1}% (paper: up to 76.2% across the 4-workload set)",
            mem.name(),
            space.describe(&joint.best),
            space.describe(&largest.best),
            max_red,
        ));
        // paper-shape check captured in the report
        let improved = (0..set.len())
            .filter(|&i| joint_scores[i] <= largest_scores[i] * 1.001)
            .count();
        report.note(format!(
            "{}: joint beats/equals largest-workload optimization on {improved}/{} workloads",
            mem.name(),
            set.len()
        ));
    }
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick_runs_and_produces_shape() {
        let ctx = ExpContext::quick(7);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 2);
        assert_eq!(r.tables[0].rows.len(), 4);
        // every score parses
        for t in &r.tables {
            for row in &t.rows {
                assert!(row[1] == "inf" || row[1].parse::<f64>().is_ok(), "{row:?}");
            }
        }
    }
}
