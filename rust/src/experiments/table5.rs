//! Table 5 (§IV-C): aggregation schemes — All (product), Max (Eq. 3) and
//! Mean — compared on RRAM and SRAM joint searches: per-workload EDAP of
//! the optimized designs plus total search time. Paper shape: comparable
//! quality across schemes, with Max cheapest and usually best.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::{Aggregation, Objective, ObjectiveKind};
use crate::report::Report;
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Table5;

impl super::Experiment for Table5 {
    fn id(&self) -> &'static str {
        "table5"
    }
    fn description(&self) -> &'static str {
        "Aggregation schemes (All/Max/Mean): design quality and search time"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Light
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let mut report = Report::new(
        "table5",
        "EDAP per optimized design and search time across aggregation strategies",
    );
    let edap = Objective::edap();

    for (mem, space) in [
        (MemoryTech::Rram, crate::space::SearchSpace::rram()),
        (MemoryTech::Sram, crate::space::SearchSpace::sram()),
    ] {
        let mut t = Table::new(
            &format!("{} — per-workload EDAP (mJ·ms·mm²) and search time", mem.name()),
            &[
                "aggregation",
                "resnet18",
                "vgg16",
                "alexnet",
                "mobilenetv3",
                "search time",
            ],
        );
        let mut times = Vec::new();
        for agg in [Aggregation::All, Aggregation::Max, Aggregation::Mean] {
            let objective = Objective::new(ObjectiveKind::Edap, agg);
            let problem = ctx.problem(&space, &set, mem, objective);
            // the GA's own wall reading is journaled with the run, so a
            // resumed table replays the recorded timing
            let result = common::ga_cell(
                ckpt,
                &format!("table5:{}:{}", mem.name(), agg.name()),
                &problem,
                common::four_phase(ctx),
                ctx.seed,
            )?;
            times.push((agg.name(), result.wall));
            // report actual per-workload EDAP of the chosen design
            let scores = common::per_workload_scores(&problem, &result.best, &edap);
            t.row(vec![
                agg.name().into(),
                common::s(scores[0]),
                common::s(scores[1]),
                common::s(scores[2]),
                common::s(scores[3]),
                ctx.fmt_wall(result.wall),
            ]);
        }
        report.table(t);
        let max_time = times
            .iter()
            .find(|(n, _)| *n == "Max")
            .map(|(_, w)| *w)
            .unwrap();
        let others_min = times
            .iter()
            .filter(|(n, _)| *n != "Max")
            .map(|(_, w)| *w)
            .min()
            .unwrap();
        report.note(format!(
            "{}: Max search time {} vs best other {} (paper: Max consistently cheapest)",
            mem.name(),
            ctx.fmt_wall(max_time),
            ctx.fmt_wall(others_min)
        ));
    }
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_quick_has_three_aggregations_per_mem() {
        let ctx = ExpContext::quick(13);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 2);
        for t in &r.tables {
            assert_eq!(t.rows.len(), 3);
            let names: Vec<&str> = t.rows.iter().map(|r| r[0].as_str()).collect();
            assert_eq!(names, vec!["All", "Max", "Mean"]);
        }
    }
}
