//! Hold-k-out portfolio matrix — `genmatrix` generalized from "leave one
//! workload out" to every k-combination of the set (`k ∈ 1..=--hold-k`,
//! default 2; the paper-breadth sweep is `--hold-k 3`).
//!
//! For each scenario family (`scenarios::paper_specs`: cnn4 on
//! weight-stationary RRAM/Max, all9 on weight-swapping SRAM/Mean) and
//! each hold-out size `k`, every `k`-combination of the set becomes a
//! [`crate::scenarios::Portfolio`]: a design is jointly optimized on the
//! other `N − k` workloads (`JointProblem::restricted_to`) and deployed
//! on the `k` held-out ones, where its per-workload EDAP is compared
//! against the separate-search specialist bound. Bounds are computed
//! once per workload and memoized through the checkpoint layer
//! (`common::separate_bound_cell`), so the C(N, k) portfolios share
//! them.
//!
//! The `k = 1` slice is the `genmatrix` experiment, bit for bit: same
//! RNG streams ([`crate::scenarios::Portfolio::joint_seed`] tags a
//! singleton deploy set with its index), same GA configuration, same
//! gap arithmetic — enforced by `rust/tests/scenario_portfolios.rs`.
//!
//! Every portfolio journals its joint search through the checkpoint
//! (resume skips completed cells) and emits a standalone JSON artifact
//! under `<out_dir>/genmatrix_k_cells/<set>-<portfolio>.json`, shape
//! pinned by `schemas/portfolio_cell.schema.json`.
//!
//! `--spec <w1>+<w2>+...:<mem>[:<agg>]` swaps the paper families for a
//! user-defined one (`scenarios::ScenarioSpec::parse`); the specialist
//! bounds ride the shared cross-experiment `bound:<set>:<w>` namespace
//! either way.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::report::Report;
use crate::scenarios;
use crate::util::stats;
use crate::util::table::Table;
use anyhow::{Context, Result};

/// Registry entry (see `experiments::REGISTRY`).
pub struct GenMatrixK;

impl super::Experiment for GenMatrixK {
    fn id(&self) -> &'static str {
        "genmatrix_k"
    }
    fn description(&self) -> &'static str {
        "Hold-k-out portfolio matrix: deploy-side EDAP gaps for every k-combination"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Heavy
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let mut report = Report::new(
        "genmatrix_k",
        "Hold-k-out portfolios: joint-on-rest vs separate-search EDAP",
    );
    let cells_dir = ctx.out_dir.join("genmatrix_k_cells");
    std::fs::create_dir_all(&cells_dir)
        .with_context(|| format!("creating {}", cells_dir.display()))?;

    // paper families, or the user-defined `--spec` family
    for spec in common::resolve_specs(ctx)? {
        let n = spec.set.len();
        anyhow::ensure!(
            n >= 2,
            "hold-k-out needs at least 2 workloads in the set ('{}' has {n}); \
             widen --spec",
            spec.name
        );
        let max_k = ctx.hold_k.clamp(1, n - 1);
        let names = spec.set.names();
        let mut summary = Table::new(
            &format!(
                "{} on {} — hold-k-out summary (gap = joint EDAP / specialist EDAP \
                 on the held-out workloads)",
                spec.name,
                spec.mem.name()
            ),
            &["k", "portfolios", "mean gap", "geo-mean gap", "worst gap", "worst held-out"],
        );
        // finite deploy gaps per (k, workload) for the per-workload table
        let mut by_workload: Vec<Vec<Vec<f64>>> = vec![vec![Vec::new(); n]; max_k];
        let mut k1_geo = f64::NAN;
        for k in 1..=max_k {
            let ports = scenarios::hold_k_out(n, k);
            let mut all_gaps: Vec<f64> = Vec::new();
            let mut worst = f64::NEG_INFINITY;
            let mut worst_label = "-".to_string();
            for p in &ports {
                // shares_joints: the k=1 slice's joints are bit-identical
                // to genmatrix's, so they replay across the two experiments
                let out = common::portfolio_cell(ckpt, "genmatrix_k", ctx, &spec, p, true)?;
                for d in &out.deploy {
                    all_gaps.push(d.gap);
                    if d.gap.is_finite() {
                        by_workload[k - 1][d.workload].push(d.gap);
                        if d.gap > worst {
                            worst = d.gap;
                            worst_label = names[d.workload].to_string();
                        }
                    }
                }
                // standalone machine-readable cell artifact (rewritten even
                // on resume so the directory is complete after any run)
                common::write_portfolio_cell(
                    &cells_dir.join(format!("{}-{}.json", spec.name, p.id)),
                    "genmatrix_k",
                    &spec,
                    p,
                    ctx.seed,
                    &out,
                )?;
            }
            let s = scenarios::summarize_gaps(&all_gaps);
            if k == 1 {
                k1_geo = s.geo_mean;
            }
            summary.row(vec![
                k.to_string(),
                ports.len().to_string(),
                common::s(s.mean),
                common::s(s.geo_mean),
                common::s(s.worst),
                worst_label,
            ]);
        }
        report.table(summary);

        let mut headers: Vec<String> = vec!["workload".into()];
        headers.extend((1..=max_k).map(|k| format!("k={k} mean gap")));
        let header_refs: Vec<&str> = headers.iter().map(|h| h.as_str()).collect();
        let mut per_wl = Table::new(
            &format!(
                "{} on {} — mean deploy gap per workload when held out",
                spec.name,
                spec.mem.name()
            ),
            &header_refs,
        );
        for wi in 0..n {
            let mut row = vec![names[wi].to_string()];
            for k in 1..=max_k {
                row.push(common::s(stats::mean(&by_workload[k - 1][wi])));
            }
            per_wl.row(row);
        }
        report.table(per_wl);

        report.note(format!(
            "{}/{}: k=1 geo-mean gap {:.3}x — the hold-one-out slice reproduces \
             `genmatrix` bit for bit (same seeds and GA configuration; enforced by \
             rust/tests/scenario_portfolios.rs). Larger k deploys on more unseen \
             workloads at once; raise the sweep with --hold-k (paper breadth: 3).",
            spec.name,
            spec.mem.name(),
            k1_geo
        ));
    }
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn hold_one_out_slice_emits_cells_and_summary() {
        let mut ctx = ExpContext::quick(53);
        ctx.hold_k = 1;
        ctx.out_dir = std::env::temp_dir().join("imcopt-genmatrix-k-test");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        // per spec: one summary + one per-workload table
        assert_eq!(r.tables.len(), 4);
        assert_eq!(r.tables[0].rows.len(), 1, "cnn4 summary sweeps k=1 only");
        assert_eq!(r.tables[1].rows.len(), 4, "cnn4 per-workload rows");
        assert_eq!(r.tables[2].rows.len(), 1, "all9 summary sweeps k=1 only");
        assert_eq!(r.tables[3].rows.len(), 9, "all9 per-workload rows");
        // one cell artifact per held-out workload, schema-shaped
        for (set, n) in [("cnn4", 4usize), ("all9", 9usize)] {
            for wi in 0..n {
                let path = ctx
                    .out_dir
                    .join("genmatrix_k_cells")
                    .join(format!("{set}-k1-{wi}.json"));
                let text = std::fs::read_to_string(&path)
                    .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                let v = json::parse(&text).unwrap();
                let p = v.get("portfolio").expect("portfolio");
                assert_eq!(p.get("k").and_then(|k| k.as_usize()), Some(1));
                assert_eq!(
                    p.get("train").and_then(|t| t.as_arr()).unwrap().len(),
                    n - 1
                );
                let gaps = v.get("deploy_gaps").and_then(|g| g.as_arr()).unwrap();
                assert_eq!(gaps.len(), 1);
                assert!(gaps[0].get("gap").unwrap().as_f64_lenient().is_some());
                // a held-out workload is never part of its own train set
                assert_eq!(gaps[0].get("in_train"), Some(&json::Json::Bool(false)));
            }
        }
    }

    #[test]
    fn spec_family_replaces_the_paper_sets() {
        let mut ctx = ExpContext::quick(57);
        ctx.hold_k = 1;
        ctx.spec = Some("resnet18+alexnet:rram".into());
        ctx.out_dir = std::env::temp_dir().join("imcopt-genmatrix-k-spec-test");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        // one summary + one per-workload table for the single custom family
        assert_eq!(r.tables.len(), 2);
        assert_eq!(r.tables[1].rows.len(), 2);
        for wi in 0..2 {
            let path = ctx
                .out_dir
                .join("genmatrix_k_cells")
                .join(format!("custom-k1-{wi}.json"));
            assert!(path.exists(), "{}", path.display());
        }
        // a single-workload spec cannot hold anything out
        ctx.spec = Some("alexnet:rram".into());
        assert!(run(&ctx, &mut Checkpoint::disabled()).is_err());
    }
}
