//! Ablations beyond the paper's figures, covering the design choices
//! DESIGN.md calls out and the §V-D runtime extensions:
//!
//! 1. replication-cap (`REP_MAX`) sensitivity of the joint-vs-largest gap
//!    (an evaluator modelling choice; the paper's trade-off needs a
//!    bounded fan-out),
//! 2. sampling pool sizes (`P_H`/`P_E`) vs final quality,
//! 3. phase-schedule ablation: full 4-phase vs exploration-only vs
//!    fine-tuning-only at equal budget,
//! 4. early stopping (§V-D): evaluations saved vs quality lost,
//! 5. surrogate-assisted sampling (§V-D): evaluations saved vs quality.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::Objective;
use crate::report::Report;
use crate::search::ga::PAPER_PHASES;
use crate::search::{
    surrogate, EarlyStop, GaConfig, GeneticAlgorithm, InitStrategy, Optimizer,
    PhaseParams, Problem,
};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Ablations;

impl super::Experiment for Ablations {
    fn id(&self) -> &'static str {
        "ablations"
    }
    fn description(&self) -> &'static str {
        "Design-choice ablations: phases, sampling pools, early stop, surrogate"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Heavy
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Experiment
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, _ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let space = crate::space::SearchSpace::rram();
    let objective = Objective::edap();
    let mut report = Report::new("ablations", "Design-choice ablations (RRAM, EDAP)");

    // ---- 1. phase-schedule ablation ----------------------------------------
    let mut t = Table::new(
        "Phase schedule at equal budget",
        &["schedule", "best EDAP", "evals"],
    );
    let schedules: Vec<(&str, Vec<PhaseParams>)> = vec![
        ("4-phase (paper)", PAPER_PHASES.to_vec()),
        ("exploration only", vec![PAPER_PHASES[0]]),
        ("fine-tuning only", vec![PAPER_PHASES[3]]),
        (
            "2-phase (explore+fine)",
            vec![PAPER_PHASES[0], PAPER_PHASES[3]],
        ),
    ];
    let (p_h, p_e) = ctx.sampling();
    for (name, phases) in schedules {
        let p = ctx.problem(&space, &set, MemoryTech::Rram, objective);
        let cfg = GaConfig {
            phases,
            init: InitStrategy::HammingDiverse { p_h, p_e },
            budget: ctx.budget(),
            elites: 2,
            early_stop: None,
            top_k: 5,
            screen_frac: ctx.screen_frac,
            label: name.into(),
        };
        let r = GeneticAlgorithm::new(cfg).run(&p, &mut Rng::seed_from(ctx.seed));
        t.row(vec![
            name.into(),
            common::s(r.best_score),
            r.evals.to_string(),
        ]);
    }
    report.table(t);

    // ---- 2. sampling pool sizes ------------------------------------------------
    let mut t = Table::new(
        "Hamming-sampling pool sizes (P_H / P_E)",
        &["P_H", "P_E", "best EDAP", "evals"],
    );
    let pools = if ctx.quick {
        vec![(40, 20), (80, 40)]
    } else {
        vec![(100, 50), (400, 200), (1000, 500), (2000, 1000)]
    };
    for (ph, pe) in pools {
        let p = ctx.problem(&space, &set, MemoryTech::Rram, objective);
        let cfg = GaConfig {
            init: InitStrategy::HammingDiverse { p_h: ph, p_e: pe },
            ..GaConfig::four_phase(ctx.budget())
        };
        let r = GeneticAlgorithm::new(cfg).run(&p, &mut Rng::seed_from(ctx.seed));
        t.row(vec![
            ph.to_string(),
            pe.to_string(),
            common::s(r.best_score),
            r.evals.to_string(),
        ]);
    }
    report.table(t);

    // ---- 3. early stopping ---------------------------------------------------------
    let mut t = Table::new(
        "Early stopping (§V-D)",
        &["policy", "best EDAP", "evals", "evals saved %"],
    );
    let mut base_evals = 0usize;
    for (name, es) in [
        ("off", None),
        ("patience 3 / 0.1%", Some(EarlyStop::default_policy())),
        (
            "patience 2 / 1%",
            Some(EarlyStop {
                patience: 2,
                min_rel_improve: 1e-2,
            }),
        ),
    ] {
        let p = ctx.problem(&space, &set, MemoryTech::Rram, objective);
        let cfg = GaConfig {
            early_stop: es,
            init: InitStrategy::HammingDiverse { p_h, p_e },
            ..GaConfig::four_phase(ctx.budget())
        };
        let r = GeneticAlgorithm::new(cfg).run(&p, &mut Rng::seed_from(ctx.seed));
        if es.is_none() {
            base_evals = r.evals;
        }
        let saved = 100.0 * (1.0 - r.evals as f64 / base_evals.max(1) as f64);
        t.row(vec![
            name.into(),
            common::s(r.best_score),
            r.evals.to_string(),
            format!("{saved:.0}"),
        ]);
    }
    report.table(t);

    // ---- 4. surrogate-assisted sampling ----------------------------------------------
    let mut t = Table::new(
        "Surrogate-assisted sampling (§V-D)",
        &["sampler", "init evals", "best-of-init EDAP"],
    );
    {
        let p = ctx.problem(&space, &set, MemoryTech::Rram, objective);
        let mut rng = Rng::seed_from(ctx.seed);
        let (full_init, full_evals) =
            crate::search::sampling::hamming_init(&p, p_h, p_e, ctx.budget().pop, &mut rng);
        let full_best = p
            .score_batch(&full_init)
            .into_iter()
            .fold(f64::INFINITY, f64::min);

        let p2 = ctx.problem(&space, &set, MemoryTech::Rram, objective);
        let mut rng = Rng::seed_from(ctx.seed);
        let train_n = (p_e / 3).max(surrogate::N_FEATURES + 2);
        let (sur_init, sur_evals) =
            surrogate::surrogate_init(&p2, p_h, p_e, ctx.budget().pop, train_n, &mut rng);
        let sur_best = p2
            .score_batch(&sur_init)
            .into_iter()
            .fold(f64::INFINITY, f64::min);

        t.row(vec![
            "full Hamming sampling".into(),
            full_evals.to_string(),
            common::s(full_best),
        ]);
        t.row(vec![
            "surrogate prescreen".into(),
            sur_evals.to_string(),
            common::s(sur_best),
        ]);
        report.note(format!(
            "surrogate sampler spends {:.0}% of the full sampler's evaluations",
            100.0 * sur_evals as f64 / full_evals.max(1) as f64
        ));
    }
    report.table(t);

    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_quick_run() {
        let ctx = ExpContext::quick(51);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 4);
        // early-stopping rows: saving percentage parses
        for row in &r.tables[2].rows {
            let _: f64 = row[3].parse().unwrap();
        }
        // surrogate never spends more init evals than the full sampler
        // (at quick-mode pool sizes the ridge fit can degenerate and fall
        // back to full evaluation, so equality is allowed; the full-scale
        // run demonstrates the strict saving)
        let full: usize = r.tables[3].rows[0][1].parse().unwrap();
        let sur: usize = r.tables[3].rows[1][1].parse().unwrap();
        assert!(sur <= full, "surrogate {sur} > full {full}");
    }
}
