//! Table 6 (§IV-E): runtime comparison at equal population size and
//! generation count — separate search, joint with the non-modified GA, and
//! the proposed joint search whose Hamming-sampling phase adds ≈30 % of
//! total search time (repeated hardware estimation of the diverse pool).

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::Objective;
use crate::report::Report;
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Registry entry (see `experiments::REGISTRY`).
pub struct Table6;

impl super::Experiment for Table6 {
    fn id(&self) -> &'static str {
        "table6"
    }
    fn description(&self) -> &'static str {
        "Runtime comparison at equal budget (wall-clock; resumes whole)"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Light
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Experiment
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

// This experiment *measures* end-to-end wall-clock, so it deliberately
// journals no cells (replayed timings would defeat its purpose); a resumed
// partial run starts over, and only the completed-report marker is
// replayed. Under `--stable` its timing cells render as "-".
pub fn run(ctx: &ExpContext, _ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let objective = Objective::edap();
    let mut report = Report::new(
        "table6",
        "Runtime comparison at equal population size and generations",
    );

    let mut t = Table::new(
        "Search / sampling / total wall-clock (this testbed; paper trends are relative)",
        &["method", "memory", "sampling time", "total time", "sampling %"],
    );

    for (mem, space) in [
        (MemoryTech::Rram, crate::space::SearchSpace::rram()),
        (MemoryTech::Sram, crate::space::SearchSpace::sram()),
    ] {
        // --- separate search: sum over workloads ---------------------------
        let t0 = Instant::now();
        for wi in 0..set.len() {
            let p = ctx.problem(&space, &set, mem, objective).restricted(wi);
            let _ = common::run_ga(&p, common::four_phase(ctx), ctx.seed);
        }
        let sep_total = t0.elapsed();
        t.row(vec![
            "separate (all workloads)".into(),
            mem.name().into(),
            "-".into(),
            ctx.fmt_wall(sep_total),
            "-".into(),
        ]);

        // --- joint, non-modified GA ------------------------------------------
        let p = ctx.problem(&space, &set, mem, objective);
        let t0 = Instant::now();
        let _ = common::run_ga(&p, common::classic(ctx), ctx.seed);
        let nonmod_total = t0.elapsed();
        t.row(vec![
            "joint (non-modified)".into(),
            mem.name().into(),
            "-".into(),
            ctx.fmt_wall(nonmod_total),
            "-".into(),
        ]);

        // --- joint, proposed (measure the sampling phase separately) ---------
        let p = ctx.problem(&space, &set, mem, objective);
        let (p_h, p_e) = ctx.sampling();
        let budget = ctx.budget();
        let mut rng = crate::util::rng::Rng::seed_from(ctx.seed);
        let t0 = Instant::now();
        let (init, _evals) =
            crate::search::sampling::hamming_init(&p, p_h, p_e, budget.pop, &mut rng);
        let sampling_time = t0.elapsed();
        // run the 4-phase GA seeded with the sampled population by reusing
        // the standard config (its internal sampling hits the warm cache,
        // so re-running it measures only the GA phases)
        let t1 = Instant::now();
        let _ = init; // population reused via problem cache
        let r = common::run_ga(&p, common::four_phase(ctx), ctx.seed);
        let ga_time = t1.elapsed();
        let total: Duration = sampling_time + ga_time;
        let frac = sampling_time.as_secs_f64() / total.as_secs_f64().max(1e-9) * 100.0;
        t.row(vec![
            "joint (proposed)".into(),
            mem.name().into(),
            ctx.fmt_wall(sampling_time),
            ctx.fmt_wall(total),
            ctx.fmt_pct(frac),
        ]);
        report.note(format!(
            "{}: proposed joint search evals={} best={:.4}",
            mem.name(),
            r.evals,
            r.best_score
        ));
    }
    report.table(t);
    report.note(
        "paper shape: proposed > joint non-modified > separate in total time; \
         sampling phase ≈30% of the proposed method's total",
    );
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_quick_rows() {
        let ctx = ExpContext::quick(19);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables[0].rows.len(), 6); // 3 methods x 2 memories
        // proposed rows report a sampling percentage
        for row in r.tables[0].rows.iter().filter(|r| r[0].contains("proposed")) {
            assert!(row[4].ends_with('%'));
        }
    }
}
