//! Table 3 (§III-C1): comparison of evolutionary optimizers on the reduced
//! RRAM search space (crossbar rows/cols, macros-per-tile, bits-per-cell;
//! 300 points) that is first *exhaustively* evaluated to locate the global
//! and local minima. Paper result: GA/ES/ERES reach the global minimum
//! (GA fastest, ≈1.5× over ES/ERES); PSO and G3PCX stall in local minima;
//! CMA-ES fails to converge.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::Objective;
use crate::report::Report;
use crate::search::{
    Exhaustive, EvolutionStrategy, G3Pcx, GaConfig, GeneticAlgorithm, Optimizer, Pso,
    SearchBudget, CmaEs,
};
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;
use std::time::Duration;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Table3;

impl super::Experiment for Table3 {
    fn id(&self) -> &'static str {
        "table3"
    }
    fn description(&self) -> &'static str {
        "Optimizer comparison on the exhaustively-scored reduced RRAM space"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Medium
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let space = crate::space::SearchSpace::rram_reduced();
    let objective = Objective::edap();
    let mut report = Report::new(
        "table3",
        "Optimizer comparison on the reduced RRAM space (exhaustive ground truth)",
    );

    // ---- exhaustive ground truth -----------------------------------------
    let problem = ctx.problem(&space, &set, MemoryTech::Rram, objective);
    let ex = Exhaustive::default();
    let scored = ex.score_all(&problem);
    let global_min = scored
        .iter()
        .map(|(_, s)| *s)
        .fold(f64::INFINITY, f64::min);
    let minima = ex.local_minima(&problem, &scored);
    report.note(format!(
        "reduced space: {} designs, global min EDAP {:.4}, {} single-move local minima",
        scored.len(),
        global_min,
        minima.len()
    ));

    // ---- algorithms under an equal budget ---------------------------------
    // deliberately below exhaustive coverage (768 designs) so convergence
    // behaviour can differ between algorithms, as in the paper
    let budget = if ctx.quick {
        SearchBudget { pop: 16, gens: 10 }
    } else {
        SearchBudget { pop: 30, gens: 20 }
    };
    let seeds: Vec<u64> = (0..ctx.repeats(5) as u64)
        .map(|i| ctx.seed.wrapping_add(i * 101))
        .collect();

    let algos: Vec<Box<dyn Optimizer>> = vec![
        Box::new(GeneticAlgorithm::new(GaConfig::classic(budget))),
        Box::new(EvolutionStrategy::plain(budget)),
        Box::new(EvolutionStrategy::eres(budget)),
        Box::new(Pso::new(budget)),
        Box::new(G3Pcx::new(budget)),
        Box::new(CmaEs::new(budget)),
    ];

    let mut t = Table::new(
        "Algorithm comparison (paper Table 3)",
        &[
            "algorithm",
            "global-min hit rate",
            "mean best EDAP",
            "mean time",
            "relative speed",
        ],
    );
    let tol = 1.0 + 1e-6;
    let mut rows: Vec<(String, f64, f64, Duration)> = Vec::new();
    for (ai, algo) in algos.iter().enumerate() {
        let mut hits = 0usize;
        let mut bests = Vec::new();
        let mut wall = Duration::ZERO;
        for &seed in &seeds {
            // fresh problem per run: timing must include evaluation work
            // (journaled runs replay their recorded wall time)
            let p = ctx.problem(&space, &set, MemoryTech::Rram, objective);
            let r = common::opt_cell(ckpt, &format!("table3:a{ai}:{seed}"), || {
                algo.run(&p, &mut crate::util::rng::Rng::seed_from(seed))
            })?;
            if r.best_score <= global_min * tol {
                hits += 1;
            }
            bests.push(r.best_score);
            wall += r.wall;
        }
        rows.push((
            algo.name(),
            hits as f64 / seeds.len() as f64,
            crate::util::stats::mean(&bests),
            wall / seeds.len() as u32,
        ));
    }
    let fastest = rows
        .iter()
        .filter(|r| r.1 >= 0.99) // among global-min finders
        .map(|r| r.3)
        .min()
        .unwrap_or_else(|| rows.iter().map(|r| r.3).min().unwrap());
    for (name, hit, mean_best, wall) in &rows {
        t.row(vec![
            name.clone(),
            format!("{:.0}%", hit * 100.0),
            crate::util::fmt_sig(*mean_best, 5),
            ctx.fmt_wall(*wall),
            ctx.fmt_ratio(wall.as_secs_f64() / fastest.as_secs_f64().max(1e-9)),
        ]);
    }
    report.table(t);
    report.note(
        "paper shape: GA/ES/ERES reach the global minimum, GA fastest; \
         PSO/G3PCX local minima; CMA-ES no convergence",
    );
    report.note(
        "measured: ES/ERES most reliable, GA markedly cheaper per run but \
         with a lower hit rate on this landscape, G3PCX/CMA-ES weakest — \
         the exact per-algorithm ordering is landscape-dependent (our \
         closed-form evaluator is smoother than CIMLoop); the robust \
         common finding is that elitist evolutionary methods dominate \
         parent-centric/covariance methods on this discrete space",
    );
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_quick_ranks_ga_at_global_min() {
        let ctx = ExpContext::quick(11);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        let t = &r.tables[0];
        assert_eq!(t.rows.len(), 6);
        // GA row present and with a finite mean best; the densified
        // reduced space is deliberately non-trivial, so the hit rate is
        // landscape-dependent rather than pinned at 100%
        let ga = &t.rows[0];
        assert_eq!(ga[0], "GA (non-modified)");
        assert!(ga[1].ends_with('%'));
        let mean: f64 = ga[2].parse().or_else(|_| ga[2].replace("e", "E").parse()).unwrap_or(f64::NAN);
        assert!(mean.is_finite(), "GA mean best = {}", ga[2]);
    }
}
