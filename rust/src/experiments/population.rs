//! Synthetic-workload population study — the paper's generalization
//! claims at hundreds-of-workloads scale instead of 9 fixed nets.
//!
//! The default family is `synth:mixed:200:<seed>` (200 generator-sampled
//! CNNs/transformers, see [`crate::ingest::WorkloadDistribution`]);
//! `--spec` swaps in any other family — another `synth:` token, file
//! paths, or canonical names. Three `transfer`-style portfolios are
//! scored ([`crate::scenarios::split_transfer_portfolios`] at an even
//! split): joint-on-half deployed on the unseen half, joint-on-half
//! deployed everywhere, and the all-joint reference. Per-workload
//! specialist bounds ride the shared cross-experiment
//! `bound:<set>:<w>` namespace, one checkpointed cell each, so resume
//! replays the whole population with zero recompute.
//!
//! Per-workload compile cost is amortized exactly like the 9 hand-coded
//! nets: every synthetic geometry falls on the compiled evaluator's
//! `(rows, cols, dpw)` grid (`model::compiled` builds buckets for every
//! grid point regardless of layer shapes), so the O(1) path serves all
//! ~10⁵ evaluations — the run reports the off-grid fallback counter to
//! prove it.
//!
//! Artifacts: one JSON cell per portfolio under
//! `<out_dir>/population_cells/`, shape pinned by
//! `schemas/portfolio_cell.schema.json`.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::report::Report;
use crate::scenarios::{self, ScenarioSpec};
use crate::util::stats;
use crate::util::table::Table;
use anyhow::{Context, Result};

/// Registry entry (see `experiments::REGISTRY`).
pub struct Population;

impl super::Experiment for Population {
    fn id(&self) -> &'static str {
        "population"
    }
    fn description(&self) -> &'static str {
        "Synthetic-workload population: transfer-style gaps over 200 generated nets"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Heavy
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

/// The family under study: `--spec` verbatim, else the default
/// 200-member mixed population seeded by `--seed` (both are part of the
/// checkpoint config fingerprint, so resumed runs always regenerate the
/// identical family).
fn family(ctx: &ExpContext) -> Result<ScenarioSpec> {
    match &ctx.spec {
        Some(s) => ScenarioSpec::parse(s),
        None => ScenarioSpec::parse(&format!("synth:mixed:200:{}", ctx.seed)),
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let mut report = Report::new(
        "population",
        "Synthetic-workload population: joint designs scored against per-net specialists",
    );
    let cells_dir = ctx.out_dir.join("population_cells");
    std::fs::create_dir_all(&cells_dir)
        .with_context(|| format!("creating {}", cells_dir.display()))?;

    let spec = family(ctx)?;
    let n = spec.set.len();
    anyhow::ensure!(
        n >= 4,
        "the population experiment needs at least 4 workloads ('{}' has {n}); \
         widen --spec",
        spec.name
    );
    let offgrid_before = crate::model::offgrid_fallbacks();
    let names = spec.set.names();
    // transformer-style nets carry dynamic attention matmuls; CNNs don't
    let kinds: Vec<&str> = spec
        .set
        .workloads
        .iter()
        .map(|w| {
            if w.layers.iter().any(|l| l.dynamic()) {
                "transformer"
            } else {
                "cnn"
            }
        })
        .collect();

    let mut summary = Table::new(
        &format!(
            "{} on {} — population portfolios (gap = joint EDAP / specialist EDAP)",
            spec.name,
            spec.mem.name()
        ),
        &[
            "portfolio",
            "train",
            "deploy",
            "mean gap",
            "geo-mean gap",
            "worst gap",
            "infeasible",
        ],
    );
    let mut all_joint_gaps: Vec<f64> = Vec::new();
    let mut all_joint_deploy: Vec<(usize, f64)> = Vec::new();
    for p in scenarios::split_transfer_portfolios(n, n / 2) {
        let out = common::portfolio_cell(ckpt, "population", ctx, &spec, &p, false)?;
        if p.id == "all-joint" {
            all_joint_gaps = out.deploy.iter().map(|d| d.gap).collect();
            all_joint_deploy = out.deploy.iter().map(|d| (d.workload, d.gap)).collect();
        }
        summary.row(vec![
            p.id.clone(),
            p.train.len().to_string(),
            p.deploy.len().to_string(),
            common::s(out.summary.mean),
            common::s(out.summary.geo_mean),
            common::s(out.summary.worst),
            format!("{:.1}%", common::infeasible_rate(&out) * 100.0),
        ]);
        common::write_portfolio_cell(
            &cells_dir.join(format!("{}-{}.json", spec.name, p.id)),
            "population",
            &spec,
            &p,
            ctx.seed,
            &out,
        )?;
    }
    report.table(summary);

    // gap distribution across the population (all-joint portfolio)
    let mut finite: Vec<f64> = all_joint_gaps
        .iter()
        .copied()
        .filter(|g| g.is_finite())
        .collect();
    finite.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut dist = Table::new(
        &format!(
            "{} — all-joint gap distribution over {} nets",
            spec.name, n
        ),
        &["finite", "p10", "p50", "p90", "worst", "worst net"],
    );
    let worst = scenarios::summarize_gaps(&all_joint_gaps);
    dist.row(vec![
        format!("{}/{}", worst.finite, worst.total),
        pctl(&finite, 0.10),
        pctl(&finite, 0.50),
        pctl(&finite, 0.90),
        common::s(worst.worst),
        worst
            .worst_at
            .map_or("-".to_string(), |i| names[all_joint_deploy[i].0].to_string()),
    ]);
    report.table(dist);

    // per-kind breakdown of the same gaps
    let mut per_kind = Table::new(
        &format!("{} — all-joint gaps by network kind", spec.name),
        &["kind", "nets", "mean gap", "geo-mean gap", "worst gap"],
    );
    for kind in ["cnn", "transformer"] {
        let gaps: Vec<f64> = all_joint_deploy
            .iter()
            .filter(|(wi, _)| kinds[*wi] == kind)
            .map(|&(_, g)| g)
            .collect();
        if gaps.is_empty() {
            continue;
        }
        let s = scenarios::summarize_gaps(&gaps);
        per_kind.row(vec![
            kind.to_string(),
            gaps.len().to_string(),
            common::s(s.mean),
            common::s(s.geo_mean),
            common::s(s.worst),
        ]);
    }
    report.table(per_kind);

    let offgrid = crate::model::offgrid_fallbacks() - offgrid_before;
    report.note(format!(
        "{} nets through the compiled evaluator with {} off-grid fallback(s) — \
         every generated geometry lands on the (rows, cols, dpw) grid, so per-net \
         compile cost is one aggregate-table build amortized over all evaluations. \
         The family is a pure function of the `--spec` token (member i derives its \
         RNG from (distribution, seed, i)), bit-identical across --threads, \
         --workers and --resume.",
        n, offgrid
    ));
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

fn pctl(sorted_finite: &[f64], q: f64) -> String {
    if sorted_finite.is_empty() {
        "-".into()
    } else {
        common::s(stats::percentile_sorted(sorted_finite, q))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn small_synth_family_runs_and_emits_cells() {
        let mut ctx = ExpContext::quick(61);
        ctx.spec = Some("synth:mixed:6:11:rram".into());
        ctx.out_dir = std::env::temp_dir().join("imcopt-population-test");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 3, "summary + distribution + per-kind");
        assert_eq!(r.tables[0].rows.len(), 3, "three portfolios");
        for pid in ["head3-to-extras", "head3-to-all", "all-joint"] {
            let path = ctx
                .out_dir
                .join("population_cells")
                .join(format!("synth-mixed6-s11-{pid}.json"));
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            let v = json::parse(&text).unwrap();
            assert_eq!(
                v.get("experiment").and_then(|e| e.as_str()),
                Some("population")
            );
            let gaps = v.get("deploy_gaps").and_then(|g| g.as_arr()).unwrap();
            assert!(!gaps.is_empty());
        }
        // the all-joint row deploys on the full population
        assert_eq!(r.tables[0].rows[2][2], "6");
    }

    #[test]
    fn default_family_is_200_mixed_nets_seeded_by_ctx() {
        let ctx = ExpContext::quick(5);
        let spec = family(&ctx).unwrap();
        assert_eq!(spec.name, "synth-mixed200-s5");
        assert_eq!(spec.set.len(), 200);
        // names are unique (they key the shared bound namespace)
        let mut names = spec.set.names();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 200);
    }

    #[test]
    fn tiny_spec_is_rejected() {
        let mut ctx = ExpContext::quick(5);
        ctx.spec = Some("resnet18+alexnet:rram".into());
        ctx.out_dir = std::env::temp_dir().join("imcopt-population-tiny-test");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        assert!(run(&ctx, &mut Checkpoint::disabled()).is_err());
    }
}
