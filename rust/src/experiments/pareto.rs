//! Pareto fronts of the joint co-optimization problem — the scalarized
//! EDAP number opened up into explicit trade-off surfaces.
//!
//! For each scenario family (`scenarios::paper_specs`, or the `--spec`
//! family) the experiment runs, at **equal search budget**:
//!
//! * the scalarized four-phase GA (the paper's optimizer) as the
//!   single-point reference, and
//! * NSGA-II ([`crate::pareto::Nsga2`]) once per `--moo-mode`:
//!   **metric** — axes `(agg(E), agg(L), A)`, whose product is the
//!   scalar EDAP, so the front's minimum-product corner lands in the
//!   same units as the GA best; **workload** — one EDAP axis per
//!   workload, the literal cross-workload trade-off front behind the
//!   paper's "one design serves many workloads" claim.
//!
//! Both optimizers share one `JointProblem` (and therefore one memo
//! cache, threading pipeline and compiled evaluator). Every search is a
//! checkpoint cell, so `--resume` replays completed fronts; per-front
//! JSON artifacts land in `<out_dir>/pareto_fronts/<set>-<mode>.json`,
//! shape pinned by `schemas/pareto_front.schema.json` and validated by
//! `imcopt validate --out-dir`. Report tables compare the knee point
//! (best compromise) and the minimum-EDAP corner against the
//! scalarized GA best; `--pareto-cap` bounds the archived front.
//!
//! Determinism: fronts, indicators and artifacts are pure functions of
//! (seed, config) — bit-identical across `--threads` settings and
//! kill/`--resume` replays (`rust/tests/pareto_front.rs`).

use super::checkpoint::{self, Checkpoint};
use super::common;
use crate::coordinator::ExpContext;
use crate::pareto::{
    indicators, MooMode, MooProblem, MooResult, MultiObjectiveOptimizer, Nsga2, Nsga2Config,
};
use crate::report::Report;
use crate::search::{GaConfig, InitStrategy, Problem};
use crate::space::Design;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;
use anyhow::{Context, Result};

/// Registry entry (see `experiments::REGISTRY`).
pub struct Pareto;

impl super::Experiment for Pareto {
    fn id(&self) -> &'static str {
        "pareto"
    }
    fn description(&self) -> &'static str {
        "NSGA-II Pareto fronts: energy/latency/area and per-workload EDAP trade-offs"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Medium
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

/// The modes to sweep: `--moo-mode metric|workload` selects one,
/// `both`/unset runs both.
fn selected_modes(ctx: &ExpContext) -> Result<Vec<MooMode>> {
    match ctx.moo_mode.as_deref() {
        None | Some("both") => Ok(vec![MooMode::Metric, MooMode::Workload]),
        Some(s) => Ok(vec![MooMode::parse(s)?]),
    }
}

/// NSGA-II sized by the context — the exact budget and sampling pools of
/// the scalarized GA it is compared against.
fn nsga_config(ctx: &ExpContext) -> Nsga2Config {
    let (p_h, p_e) = ctx.sampling();
    Nsga2Config {
        init: InitStrategy::HammingDiverse { p_h, p_e },
        cap: ctx.pareto_cap,
        screen_frac: ctx.screen_frac,
        ..Nsga2Config::paper(ctx.budget())
    }
}

/// One seed per scenario family, shared by the GA reference and every
/// NSGA-II mode: both searches then draw the *same* Hamming-sampled
/// initial population, so the corner-vs-best comparison starts from a
/// common anchor and isolates the selection strategy.
fn family_seed(base: u64, si: usize) -> u64 {
    base.wrapping_add(si as u64 * 12007)
}

/// Journal a [`MooResult`] as a checkpoint cell.
fn moo_cell(
    ckpt: &mut Checkpoint,
    key: &str,
    compute: impl FnOnce() -> MooResult,
) -> Result<MooResult> {
    let v = ckpt.cell(key, || Ok(moo_result_to_json(&compute())))?;
    moo_result_from_json(&v)
}

/// Serialize a multi-objective result (journal cell payload).
pub fn moo_result_to_json(r: &MooResult) -> Json {
    Json::obj(vec![
        ("algorithm", Json::Str(r.algorithm.clone())),
        (
            "front",
            Json::Arr(
                r.front
                    .iter()
                    .map(|(d, o)| {
                        Json::Arr(vec![
                            checkpoint::design_to_json(d),
                            Json::Arr(o.iter().map(|&x| Json::f64(x)).collect()),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "front_sizes",
            Json::Arr(r.front_sizes.iter().map(|&n| Json::Num(n as f64)).collect()),
        ),
        ("evals", Json::Num(r.evals as f64)),
        ("wall_us", Json::Num(r.wall.as_micros() as f64)),
    ])
}

/// Deserialize a result journaled by [`moo_result_to_json`].
pub fn moo_result_from_json(v: &Json) -> Result<MooResult> {
    let front = v
        .get("front")
        .and_then(|f| f.as_arr())
        .context("moo result: missing 'front'")?
        .iter()
        .map(|pair| -> Result<(Design, Vec<f64>)> {
            let pair = pair.as_arr().context("front entry: expected a pair")?;
            anyhow::ensure!(pair.len() == 2, "front entry: expected [design, objectives]");
            let objs = pair[1]
                .as_arr()
                .context("front objectives: expected an array")?
                .iter()
                .map(|x| x.as_f64_lenient().context("objective: expected a number"))
                .collect::<Result<Vec<f64>>>()?;
            Ok((checkpoint::design_from_json(&pair[0])?, objs))
        })
        .collect::<Result<Vec<_>>>()?;
    let front_sizes = v
        .get("front_sizes")
        .and_then(|f| f.as_arr())
        .context("moo result: missing 'front_sizes'")?
        .iter()
        .map(|x| x.as_usize().context("front size: expected a number"))
        .collect::<Result<Vec<usize>>>()?;
    Ok(MooResult {
        algorithm: v
            .get("algorithm")
            .and_then(|a| a.as_str())
            .context("moo result: missing 'algorithm'")?
            .to_string(),
        front,
        front_sizes,
        evals: v
            .get("evals")
            .and_then(|x| x.as_usize())
            .context("moo result: missing 'evals'")?,
        wall: std::time::Duration::from_micros(
            v.get("wall_us")
                .and_then(|x| x.as_f64_lenient())
                .context("moo result: missing 'wall_us'")? as u64,
        ),
    })
}

/// Index of the minimum finite scalar (first on ties); `None` when no
/// entry is finite.
fn argmin_scalar(scalars: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &s) in scalars.iter().enumerate() {
        if !s.is_finite() {
            continue;
        }
        match best {
            Some((_, b)) if s >= b => {}
            _ => best = Some((i, s)),
        }
    }
    best.map(|(i, _)| i)
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let mut report = Report::new(
        "pareto",
        "NSGA-II Pareto fronts vs the scalarized four-phase GA (equal budget)",
    );
    let fronts_dir = ctx.out_dir.join("pareto_fronts");
    // every run (fresh or resumed) rewrites the complete front set for
    // its configuration, so clearing first guarantees the directory
    // never mixes fronts from differently-configured sweeps (a stale
    // `--seed`/`--moo-mode`/`--spec` artifact would otherwise survive
    // and pass `imcopt validate` as if it belonged to this run)
    if fronts_dir.exists() {
        std::fs::remove_dir_all(&fronts_dir)
            .with_context(|| format!("clearing {}", fronts_dir.display()))?;
    }
    std::fs::create_dir_all(&fronts_dir)
        .with_context(|| format!("creating {}", fronts_dir.display()))?;
    let modes = selected_modes(ctx)?;

    let mut summary = Table::new(
        "front quality and corner comparison (corner = minimum-EDAP front point; \
         GA best = scalarized four-phase GA at the same budget and seed)",
        &[
            "set", "mode", "axes", "front", "hv(norm)", "spacing", "knee EDAP",
            "corner EDAP", "GA best EDAP", "corner/GA",
        ],
    );

    for (si, spec) in common::resolve_specs(ctx)?.iter().enumerate() {
        let problem = ctx.spec_problem(spec);
        ckpt.warm_problem(&problem);
        // the accuracy floor needs a nominal-accuracy model for every
        // workload of the family; sets without full baselines keep their
        // plain fronts and say so in the report
        let floor = match ctx.acc_floor {
            Some(f)
                if spec
                    .set
                    .workloads
                    .iter()
                    .all(|w| crate::accuracy::has_baseline(&w.name)) =>
            {
                Some(f)
            }
            Some(f) => {
                ctx.record_notice(format!(
                    "--acc-floor {f} ignored for set '{}': not every workload \
                     carries an accuracy baseline",
                    spec.name
                ));
                None
            }
            None => None,
        };
        let seed = family_seed(ctx.seed, si);

        // scalarized reference at the same budget
        let ga_cfg = GaConfig {
            top_k: ctx.top_k,
            ..common::four_phase(ctx)
        };
        let ga = common::ga_cell(
            ckpt,
            &format!("pareto:{}:ga", spec.name),
            &problem,
            ga_cfg,
            seed,
        )?;

        for mode in &modes {
            let moo = MooProblem::new(&problem, *mode).with_acc_floor(floor);
            let mr = moo_cell(
                ckpt,
                &format!("pareto:{}:{}:front", spec.name, mode.name()),
                || Nsga2::new(nsga_config(ctx)).run(&moo, &mut Rng::seed_from(seed)),
            )?;
            let objs = mr.objective_vectors();
            let front_designs: Vec<Design> =
                mr.front.iter().map(|(d, _)| d.clone()).collect();
            // scalar joint EDAP of every front design (pure cache hits for
            // the fresh-run path; deterministic recomputation on resume)
            let scalars = problem.score_batch(&front_designs);
            let knee = indicators::knee_index(&objs);
            let corner = argmin_scalar(&scalars);
            let hv = indicators::normalized_hypervolume(&objs);
            let spc = indicators::spacing(&objs);
            let corner_scalar = corner.map(|i| scalars[i]).unwrap_or(f64::NAN);
            let ratio = if ga.best_score.is_finite() && ga.best_score > 0.0 {
                corner_scalar / ga.best_score
            } else {
                f64::NAN
            };
            let active = moo.active_indices();
            let axes = moo.vector_objective.axes(&spec.set, &active);

            summary.row(vec![
                spec.name.clone(),
                mode.name().into(),
                axes.len().to_string(),
                mr.front.len().to_string(),
                common::s(hv),
                common::s(spc),
                common::s(knee.map(|i| scalars[i]).unwrap_or(f64::NAN)),
                common::s(corner_scalar),
                common::s(ga.best_score),
                common::s(ratio),
            ]);

            // standalone machine-readable front artifact (rewritten even on
            // resume so the directory is complete after any run)
            let point_json = |i: usize| {
                Json::obj(vec![
                    ("design", checkpoint::design_to_json(&front_designs[i])),
                    ("described", Json::Str(spec.space.describe(&front_designs[i]))),
                    (
                        "objectives",
                        Json::Arr(objs[i].iter().map(|&x| Json::f64(x)).collect()),
                    ),
                    ("scalar_edap", Json::f64(scalars[i])),
                ])
            };
            let opt_point = |i: Option<usize>| match i {
                Some(i) => point_json(i),
                None => Json::Null,
            };
            let cell = Json::obj(vec![
                ("experiment", Json::Str("pareto".into())),
                ("set", Json::Str(spec.name.clone())),
                ("mem", Json::Str(spec.mem.name().into())),
                ("aggregation", Json::Str(spec.agg.name().into())),
                ("mode", Json::Str(mode.name().into())),
                (
                    "axes",
                    Json::Arr(axes.iter().map(|a| Json::Str(a.clone())).collect()),
                ),
                ("cap", Json::Num(ctx.pareto_cap as f64)),
                ("seed", Json::Num(ctx.seed as f64)),
                ("points", Json::Arr((0..mr.front.len()).map(point_json).collect())),
                (
                    "indicators",
                    Json::obj(vec![
                        ("front_size", Json::Num(mr.front.len() as f64)),
                        ("hypervolume_norm", Json::f64(hv)),
                        ("spacing", Json::f64(spc)),
                    ]),
                ),
                ("knee", opt_point(knee)),
                ("corner", opt_point(corner)),
                (
                    "ga_best",
                    Json::obj(vec![
                        ("design", checkpoint::design_to_json(&ga.best)),
                        ("described", Json::Str(spec.space.describe(&ga.best))),
                        ("scalar_edap", Json::f64(ga.best_score)),
                    ]),
                ),
                ("corner_vs_ga", Json::f64(ratio)),
            ]);
            let path = fronts_dir.join(format!("{}-{}.json", spec.name, mode.name()));
            // atomic: concurrent orchestrator workers may emit the same front
            crate::util::write_atomic(&path, &(cell.to_string() + "\n"))
                .with_context(|| format!("writing pareto front {}", path.display()))?;
        }
        ckpt.absorb_problem(&problem)?;
    }
    report.table(summary);
    report.note(
        "metric mode: axes (agg(E) mJ, agg(L) ms, A mm2) — their product is the \
         scalar EDAP, so 'corner EDAP' is directly comparable to the GA best at \
         the same budget and seed. workload mode: one EDAP axis per workload — \
         the cross-workload trade-off surface; its knee is the front's best \
         compromise across workloads. hv(norm) is the hypervolume of the \
         min-max-normalized front against the 1.1^d reference; see docs/pareto.md.",
    );
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn moo_result_codec_roundtrips_bit_exact() {
        let r = MooResult {
            algorithm: "NSGA-II (4-phase operators)".into(),
            front: vec![
                (Design(vec![1; 10]), vec![1.0 / 3.0, 2.5, 7.0]),
                (Design(vec![2; 10]), vec![0.5, f64::INFINITY, 1.0]),
            ],
            front_sizes: vec![1, 2, 2],
            evals: 480,
            wall: std::time::Duration::from_micros(123_456),
        };
        let j = moo_result_to_json(&r);
        let back = moo_result_from_json(&json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.algorithm, r.algorithm);
        assert_eq!(back.front.len(), 2);
        for ((da, oa), (db, ob)) in r.front.iter().zip(&back.front) {
            assert_eq!(da, db);
            for (x, y) in oa.iter().zip(ob) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(back.front_sizes, r.front_sizes);
        assert_eq!(back.evals, r.evals);
        assert_eq!(back.wall, r.wall);
    }

    #[test]
    fn mode_selection_honors_the_knob() {
        let mut ctx = ExpContext::quick(1);
        assert_eq!(selected_modes(&ctx).unwrap().len(), 2);
        ctx.moo_mode = Some("both".into());
        assert_eq!(selected_modes(&ctx).unwrap().len(), 2);
        ctx.moo_mode = Some("metric".into());
        assert_eq!(selected_modes(&ctx).unwrap(), vec![MooMode::Metric]);
        ctx.moo_mode = Some("workload".into());
        assert_eq!(selected_modes(&ctx).unwrap(), vec![MooMode::Workload]);
        ctx.moo_mode = Some("nope".into());
        assert!(selected_modes(&ctx).is_err());
    }

    #[test]
    fn argmin_is_nan_safe_and_first_on_ties() {
        assert_eq!(argmin_scalar(&[3.0, 1.0, 1.0, f64::NAN]), Some(1));
        assert_eq!(argmin_scalar(&[f64::INFINITY, f64::NAN]), None);
        assert_eq!(argmin_scalar(&[]), None);
    }

    #[test]
    fn quick_run_emits_fronts_for_both_sets_and_modes() {
        let mut ctx = ExpContext::quick(71);
        ctx.out_dir = std::env::temp_dir().join("imcopt-pareto-test");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 1);
        assert_eq!(r.tables[0].rows.len(), 4, "2 sets x 2 modes");
        for set in ["cnn4", "all9"] {
            for mode in ["metric", "workload"] {
                let path = ctx
                    .out_dir
                    .join("pareto_fronts")
                    .join(format!("{set}-{mode}.json"));
                let text = std::fs::read_to_string(&path)
                    .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                let v = json::parse(&text).unwrap();
                assert_eq!(v.get("experiment").and_then(|e| e.as_str()), Some("pareto"));
                assert_eq!(v.get("mode").and_then(|m| m.as_str()), Some(mode));
                let axes = v.get("axes").and_then(|a| a.as_arr()).unwrap();
                let expected = if mode == "metric" {
                    3
                } else if set == "cnn4" {
                    4
                } else {
                    9
                };
                assert_eq!(axes.len(), expected, "{set}-{mode}");
                let points = v.get("points").and_then(|p| p.as_arr()).unwrap();
                assert!(!points.is_empty(), "{set}-{mode}: empty front");
                for p in points {
                    assert_eq!(
                        p.get("objectives").and_then(|o| o.as_arr()).unwrap().len(),
                        expected
                    );
                }
                assert!(v.get("ga_best").unwrap().get("scalar_edap").is_some());
            }
        }
    }

    #[test]
    fn custom_spec_and_single_mode() {
        let mut ctx = ExpContext::quick(73);
        ctx.out_dir = std::env::temp_dir().join("imcopt-pareto-spec-test");
        ctx.spec = Some("resnet18+alexnet:rram".into());
        ctx.moo_mode = Some("workload".into());
        ctx.pareto_cap = 8;
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables[0].rows.len(), 1);
        let path = ctx.out_dir.join("pareto_fronts/custom-workload.json");
        let v = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let points = v.get("points").and_then(|p| p.as_arr()).unwrap();
        assert!(points.len() <= 8, "--pareto-cap must bound the front");
        assert_eq!(v.get("cap").and_then(|c| c.as_usize()), Some(8));
        // a re-run under a different mode must not leave the old front
        // behind: the directory always reflects exactly one configuration
        ctx.moo_mode = Some("metric".into());
        run(&ctx, &mut Checkpoint::disabled()).unwrap();
        let fronts: Vec<_> = std::fs::read_dir(ctx.out_dir.join("pareto_fronts"))
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .collect();
        assert_eq!(fronts, vec!["custom-metric.json"], "stale fronts survived");
    }
}
