//! Fig. 5 (§IV-D): the performance gap between generalized and
//! workload-specific designs, across RRAM (a–d) and SRAM (e–h) and four
//! objective functions (EDAP, EDP, energy, latency).
//!
//! For each panel: per-workload scores of (i) separate search per workload
//! (the baseline = 1.0 after normalization), (ii) separate search for the
//! largest workload evaluated on all, (iii) joint search with the
//! non-modified GA (EDAP panels), (iv) joint with enhanced sampling (EDAP
//! panels), and (v) the proposed 4-phase GA. Top-5 designs per run; the
//! paper's success criterion is the proposed method sitting closest to 1.0.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::Objective;
use crate::report::Report;
use crate::search::OptResult;
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Fig5;

impl super::Experiment for Fig5 {
    fn id(&self) -> &'static str {
        "fig5"
    }
    fn description(&self) -> &'static str {
        "Generalized vs workload-specific designs across objectives (8 panels)"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Heavy
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let mut report = Report::new(
        "fig5",
        "Generalized vs workload-specific designs across objectives (top-1 of top-5 shown)",
    );

    // The paper repeats Fig. 5 with five initial-population seeds and
    // reports consistent trends; we average the normalized scores over the
    // same number of independent runs (2 under --quick).
    let seeds: Vec<u64> = (0..ctx.repeats(5) as u64)
        .map(|i| ctx.seed.wrapping_add(i * 7919))
        .collect();

    let objectives = Objective::figure5_set();
    for (mem, space) in [
        (MemoryTech::Rram, crate::space::SearchSpace::rram()),
        (MemoryTech::Sram, crate::space::SearchSpace::sram()),
    ] {
        for objective in &objectives {
            let panel = format!("{} / {}", mem.name(), objective.name());
            let key_base = format!("fig5:{}:{}", mem.name(), objective.name());

            // (i) separate search per workload -> baseline scores
            // (best over the seed set: the workload-specific bound).
            // Each run is a checkpoint cell; the per-config eval memo is
            // persisted so a resumed in-flight run starts warm with every
            // design the earlier seeds already evaluated.
            let mut baseline = vec![f64::INFINITY; set.len()];
            for wi in 0..set.len() {
                for &seed in &seeds {
                    let p = ctx
                        .problem(&space, &set, mem, *objective)
                        .restricted(wi);
                    ckpt.warm_problem(&p);
                    let r = common::ga_cell(
                        ckpt,
                        &format!("{key_base}:base:{wi}:{seed}"),
                        &p,
                        common::four_phase(ctx),
                        seed,
                    )?;
                    ckpt.absorb_problem(&p)?;
                    let scores = common::per_workload_scores(&p, &r.best, objective);
                    baseline[wi] = baseline[wi].min(scores[wi]);
                }
            }

            // helper: normalized per-workload scores of a run's top-1
            let joint_problem = ctx.problem(&space, &set, mem, *objective);
            let normalized = |r: &OptResult| -> Vec<f64> {
                let scores =
                    common::per_workload_scores(&joint_problem, &r.best, objective);
                scores
                    .iter()
                    .zip(&baseline)
                    .map(|(s, b)| s / b)
                    .collect()
            };
            let spread = |r: &OptResult| -> f64 {
                if r.top.len() < 2 {
                    return 0.0;
                }
                let best = r.top[0].1;
                let worst = r.top.last().unwrap().1;
                if best > 0.0 && best.is_finite() {
                    worst / best - 1.0
                } else {
                    f64::NAN
                }
            };

            // strategies (GA baselines only on the EDAP panels, as in the
            // paper); each runs once per seed and reports seed-mean
            // normalized scores + seed-mean top-5 spread. Runners journal
            // through the caller-supplied cell key and share the
            // persisted per-config eval memo.
            let is_edap = objective.kind == crate::objective::ObjectiveKind::Edap;
            type Runner<'x> =
                Box<dyn Fn(&mut Checkpoint, &str, u64) -> Result<OptResult> + 'x>;
            // joint-search runners differ only in GA configuration; fn
            // pointers keep the closures borrow-only (no captured state
            // beyond the panel context)
            let joint_cfgs: Vec<(&str, fn(&ExpContext) -> crate::search::GaConfig)> =
                if is_edap {
                    vec![
                        ("joint non-modified GA", common::classic),
                        ("joint GA + sampling", common::classic_sampled),
                        ("joint 4-phase GA (proposed)", common::four_phase),
                    ]
                } else {
                    vec![("joint 4-phase GA (proposed)", common::four_phase)]
                };
            let mut strategies: Vec<(&str, Runner)> = vec![(
                "separate for largest workload",
                Box::new(|ckpt: &mut Checkpoint, key: &str, seed: u64| {
                    // §IV-A naive flow: largest workload + conventional GA
                    common::naive_largest_cell(
                        ckpt, key, ctx, &space, &set, mem, *objective, seed,
                    )
                }),
            )];
            // plain `Copy` references so the `move` closures below don't
            // take the owned space/set out of the panel scope
            let (space_ref, set_ref) = (&space, &set);
            for (name, cfg) in joint_cfgs {
                strategies.push((
                    name,
                    Box::new(move |ckpt: &mut Checkpoint, key: &str, seed: u64| {
                        let p = ctx.problem(space_ref, set_ref, mem, *objective);
                        ckpt.warm_problem(&p);
                        let r = common::ga_cell(ckpt, key, &p, cfg(ctx), seed)?;
                        ckpt.absorb_problem(&p)?;
                        Ok(r)
                    }),
                ));
            }

            let mut t = Table::new(
                &format!(
                    "panel {panel} — seed-mean scores normalized to separate search (=1.0)"
                ),
                &["strategy", "resnet18", "vgg16", "alexnet", "mobilenetv3", "top5 spread"],
            );
            t.row(vec![
                "separate (baseline)".into(),
                "1.000".into(),
                "1.000".into(),
                "1.000".into(),
                "1.000".into(),
                "-".into(),
            ]);
            let mut geo_means: Vec<(String, f64)> = Vec::new();
            for (si, (name, run)) in strategies.iter().enumerate() {
                let mut acc = vec![0.0; set.len()];
                let mut sp = 0.0;
                for &seed in &seeds {
                    // salt by strategy: the VGG-restricted and joint-Max
                    // landscapes coincide wherever the largest workload
                    // dominates, so identical RNG streams would yield
                    // artificially identical rows
                    let salted = seed.wrapping_mul(31).wrapping_add(si as u64 * 1009);
                    let r = run(ckpt, &format!("{key_base}:s{si}:{seed}"), salted)?;
                    for (a, n) in acc.iter_mut().zip(normalized(&r)) {
                        *a += n / seeds.len() as f64;
                    }
                    sp += spread(&r) / seeds.len() as f64;
                }
                t.row(vec![
                    name.to_string(),
                    format!("{:.3}", acc[0]),
                    format!("{:.3}", acc[1]),
                    format!("{:.3}", acc[2]),
                    format!("{:.3}", acc[3]),
                    format!("{:.3}", sp),
                ]);
                geo_means.push((
                    name.to_string(),
                    crate::util::stats::geo_mean(
                        &acc.iter()
                            .copied()
                            .filter(|x| x.is_finite() && *x > 0.0)
                            .collect::<Vec<_>>(),
                    ),
                ));
            }
            report.table(t);
            let gm_of = |name: &str| {
                geo_means
                    .iter()
                    .find(|(n, _)| n.contains(name))
                    .map(|(_, g)| *g)
                    .unwrap_or(f64::NAN)
            };
            report.note(format!(
                "{panel}: geo-mean gap to workload-specific (seed-mean) — \
                 largest-only {:.3}, proposed {:.3} (closer to 1.0 is better)",
                gm_of("largest"),
                gm_of("proposed")
            ));
        }
    }
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_quick_shapes() {
        let ctx = ExpContext::quick(17);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        // 2 memories x 4 objectives
        assert_eq!(r.tables.len(), 8);
        // EDAP panels carry 5 strategies, others 3
        assert_eq!(r.tables[0].rows.len(), 5);
        assert_eq!(r.tables[1].rows.len(), 3);
        // baseline row is exactly 1.0
        assert_eq!(r.tables[0].rows[0][1], "1.000");
    }
}
