//! Fig. 7 (§IV-G): ablation — joint optimization of the full parameter
//! stack vs *sequential* level-by-level optimization (device → circuit →
//! architecture → system for RRAM; starting at circuit for SRAM), with two
//! initializations: the largest configuration in the space and the
//! per-parameter median.
//!
//! Paper shape: joint wins everywhere; sequential-from-largest violates
//! the RRAM area constraint; sequential-from-median gets stuck in early
//! circuit-level choices (the MobileNetV3 lock-in story for SRAM).

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::{ExpContext, JointProblem};
use crate::model::MemoryTech;
use crate::objective::Objective;
use crate::report::Report;
use crate::search::Problem;
use crate::space::{Design, Level, SearchSpace, PARAM_LEVELS};
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Enumerate every combination of the given parameter subset around a
/// base design and return the best (joint score) design. When the whole
/// level is infeasible, fall back to minimizing the graded constraint
/// violation so later levels can recover — without this the sequential
/// baseline degenerates to arbitrary picks on infeasible plateaus.
fn optimize_level(
    problem: &JointProblem<'_>,
    base: &Design,
    params: &[usize],
) -> Design {
    let space = problem.space;
    // mixed-radix enumeration of the subset
    let radixes: Vec<usize> = params
        .iter()
        .map(|&pi| space.params[pi].cardinality())
        .collect();
    let total: usize = radixes.iter().product();
    let mut candidates = Vec::with_capacity(total);
    let mut counter = vec![0usize; params.len()];
    loop {
        let mut d = base.clone();
        for (slot, &pi) in params.iter().enumerate() {
            d.0[pi] = counter[slot] as u16;
        }
        candidates.push(d);
        let mut i = params.len();
        loop {
            if i == 0 {
                let scores = problem.score_batch(&candidates);
                let best = (0..candidates.len())
                    .min_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap())
                    .unwrap();
                if scores[best].is_finite() {
                    return candidates[best].clone();
                }
                // all infeasible: steer by graded violation
                let least_violating = (0..candidates.len())
                    .min_by(|&a, &b| {
                        problem
                            .violation(&candidates[a])
                            .partial_cmp(&problem.violation(&candidates[b]))
                            .unwrap()
                    })
                    .unwrap();
                return candidates[least_violating].clone();
            }
            i -= 1;
            counter[i] += 1;
            if counter[i] < radixes[i] {
                break;
            }
            counter[i] = 0;
        }
    }
}

/// Sequential stack optimization: levels in the given order, each level
/// exhaustively optimized with all other parameters frozen.
fn sequential(problem: &JointProblem<'_>, init: Design, order: &[Level]) -> Design {
    let mut current = init;
    for level in order {
        let params: Vec<usize> = (0..PARAM_LEVELS.len())
            .filter(|&i| {
                PARAM_LEVELS[i] == *level && problem.space.params[i].cardinality() > 1
            })
            .collect();
        if params.is_empty() {
            continue;
        }
        current = optimize_level(problem, &current, &params);
    }
    current
}

fn largest_design(space: &SearchSpace) -> Design {
    Design(
        space
            .params
            .iter()
            .map(|p| (p.cardinality() - 1) as u16)
            .collect(),
    )
}

fn median_design(space: &SearchSpace) -> Design {
    Design(
        space
            .params
            .iter()
            .map(|p| (p.cardinality() / 2) as u16)
            .collect(),
    )
}

/// Registry entry (see `experiments::REGISTRY`).
pub struct Fig7;

impl super::Experiment for Fig7 {
    fn id(&self) -> &'static str {
        "fig7"
    }
    fn description(&self) -> &'static str {
        "Joint vs sequential level-by-level optimization of the stack"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Medium
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Experiment
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, _ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let objective = Objective::edap();
    let mut report = Report::new(
        "fig7",
        "Ablation: joint vs sequential hardware-stack optimization",
    );

    for (mem, space, order) in [
        (
            MemoryTech::Rram,
            crate::space::SearchSpace::rram(),
            vec![Level::Device, Level::Circuit, Level::Architecture, Level::System],
        ),
        (
            MemoryTech::Sram,
            crate::space::SearchSpace::sram(),
            vec![Level::Circuit, Level::Architecture, Level::System],
        ),
    ] {
        let problem = ctx.problem(&space, &set, mem, objective);

        let joint = common::run_ga(&problem, common::four_phase(ctx), ctx.seed);
        let seq_largest = sequential(&problem, largest_design(&space), &order);
        let seq_median = sequential(&problem, median_design(&space), &order);

        let mut t = Table::new(
            &format!("{} — per-workload EDAP (mJ·ms·mm²)", mem.name()),
            &["strategy", "resnet18", "vgg16", "alexnet", "mobilenetv3", "joint score"],
        );
        for (name, d) in [
            ("joint (proposed)", &joint.best),
            ("sequential from largest", &seq_largest),
            ("sequential from median", &seq_median),
        ] {
            let scores = common::per_workload_scores(&problem, d, &objective);
            let joint_score = problem.score_batch(std::slice::from_ref(d))[0];
            t.row(vec![
                name.into(),
                common::s(scores[0]),
                common::s(scores[1]),
                common::s(scores[2]),
                common::s(scores[3]),
                common::s(joint_score),
            ]);
        }
        report.table(t);

        let seq_l_score = problem.score_batch(std::slice::from_ref(&seq_largest))[0];
        let seq_m_score = problem.score_batch(std::slice::from_ref(&seq_median))[0];
        report.note(format!(
            "{}: joint {} vs sequential-largest {} / sequential-median {}{}",
            mem.name(),
            common::s(joint.best_score),
            common::s(seq_l_score),
            common::s(seq_m_score),
            if mem == MemoryTech::Rram && !seq_l_score.is_finite() {
                " — sequential-from-largest violates constraints, as in the paper"
            } else {
                ""
            }
        ));
    }
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_quick_shapes_and_feasibility() {
        // Quick mode shrinks the GA budget below what the paper-scale
        // comparison needs, so this test checks structure and feasibility;
        // the full-budget run (`repro exp fig7`) carries the paper claim
        // and is asserted in the integration suite.
        let ctx = ExpContext::quick(29);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 2);
        for t in &r.tables {
            assert_eq!(t.rows.len(), 3);
            let joint: f64 = t.rows[0][5].parse().unwrap_or(f64::INFINITY);
            assert!(joint.is_finite(), "joint search must find a feasible design");
        }
    }

    #[test]
    fn level_enumeration_respects_frozen_params() {
        let ctx = ExpContext::quick(31);
        let set = WorkloadSet::cnn4();
        let space = crate::space::SearchSpace::rram();
        let p = ctx.problem(&space, &set, MemoryTech::Rram, Objective::edap());
        let base = median_design(&space);
        let out = optimize_level(&p, &base, &[crate::space::idx::BITS_CELL]);
        // only bits_cell may differ
        for i in 0..base.0.len() {
            if i != crate::space::idx::BITS_CELL {
                assert_eq!(out.0[i], base.0[i]);
            }
        }
    }
}
