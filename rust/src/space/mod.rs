//! The multi-level hardware search space (paper §III-B, Table 1 "Ours").
//!
//! Parameters span device (bits per cell), circuit (crossbar rows/cols),
//! architecture (macros per tile, tiles per router, tile groups per chip,
//! global buffer size) and system level (operating voltage, cycle time,
//! CMOS technology node). Designs are **index-coded**: a design is a vector
//! of indices into each parameter's discrete value list, which makes
//! Hamming distance (Eq. 1–2), SBX/polynomial-mutation variation and
//! exhaustive enumeration straightforward.
//!
//! Conditional dependency handling: the operating voltage is encoded as a
//! normalized *step* (0..=7) that decodes into the voltage range of the
//! design's technology node (paper Table 7), so the space stays a plain
//! product of independent domains even in hardware-workload-technology
//! co-optimization (paper §IV-I).

use crate::model::tech::voltage_range;
use crate::util::rng::Rng;

/// Number of parameters in the canonical design vector.
pub const NUM_PARAMS: usize = 10;

/// Canonical parameter order, shared with the AOT-compiled JAX evaluator
/// (see `python/compile/hwspec.py`; the cross-language consistency test
/// enforces agreement).
pub const PARAM_NAMES: [&str; NUM_PARAMS] = [
    "xbar_rows",
    "xbar_cols",
    "c_per_tile",
    "t_per_router",
    "g_per_chip",
    "bits_cell",
    "v_step",
    "t_cycle_ns",
    "glb_kb",
    "tech_nm",
];

/// Index of each parameter in the canonical order.
pub mod idx {
    pub const ROWS: usize = 0;
    pub const COLS: usize = 1;
    pub const C_PER_TILE: usize = 2;
    pub const T_PER_ROUTER: usize = 3;
    pub const G_PER_CHIP: usize = 4;
    pub const BITS_CELL: usize = 5;
    pub const V_STEP: usize = 6;
    pub const T_CYCLE_NS: usize = 7;
    pub const GLB_KB: usize = 8;
    pub const TECH_NM: usize = 9;
}

/// Hardware stack level of a parameter (paper Table 1: D/C/A/S columns).
/// Drives the sequential-optimization ablation of Fig. 7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    Device,
    Circuit,
    Architecture,
    System,
}

/// Level of each canonical parameter.
pub const PARAM_LEVELS: [Level; NUM_PARAMS] = [
    Level::Circuit,      // xbar_rows
    Level::Circuit,      // xbar_cols
    Level::Architecture, // c_per_tile
    Level::Architecture, // t_per_router
    Level::Architecture, // g_per_chip
    Level::Device,       // bits_cell
    Level::System,       // v_step
    Level::System,       // t_cycle_ns
    Level::Architecture, // glb_kb (buffer size — architecture per Table 1)
    Level::System,       // tech_nm
];

/// One discrete parameter domain.
#[derive(Clone, Debug)]
pub struct ParamDef {
    pub name: &'static str,
    pub values: Vec<f64>,
}

impl ParamDef {
    pub fn cardinality(&self) -> usize {
        self.values.len()
    }
}

/// A candidate hardware design: one index per parameter.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Design(pub Vec<u16>);

impl Design {
    /// Hamming distance (paper Eq. 1–2): number of differing parameters.
    pub fn hamming(&self, other: &Design) -> usize {
        debug_assert_eq!(self.0.len(), other.0.len());
        self.0
            .iter()
            .zip(&other.0)
            .filter(|(a, b)| a != b)
            .count()
    }
}

/// The full search space for one experiment configuration.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub params: Vec<ParamDef>,
    /// Human-readable variant name ("rram-32nm", "sram-32nm", "sram-tech").
    pub variant: &'static str,
}

/// Union of every variant's crossbar row/col grid (the dense
/// reduced-space grid is a superset of the full-space `ROWS_COLS`).
/// The compiled evaluator (`model::compiled`) precomputes one shape
/// bucket per (rows, cols, dpw) drawn from this — extend it here, and
/// the buckets follow; a value used by a space but missing here would
/// silently drop that space to the naive layer walk.
pub const ALL_ROWS_COLS: [f64; 8] = [32.0, 64.0, 96.0, 128.0, 192.0, 256.0, 384.0, 512.0];

/// Union of every variant's bits-per-cell domain (SRAM uses the `1.0`
/// subset). Shared with `model::compiled` like [`ALL_ROWS_COLS`].
pub const ALL_BITS_CELL: [f64; 3] = [1.0, 2.0, 4.0];

const ROWS_COLS: [f64; 5] = [32.0, 64.0, 128.0, 256.0, 512.0];
const C_PER_TILE: [f64; 4] = [4.0, 8.0, 16.0, 32.0];
const T_PER_ROUTER: [f64; 4] = [2.0, 4.0, 8.0, 16.0];
const G_PER_CHIP: [f64; 10] = [2.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0];
const T_CYCLE_NS: [f64; 4] = [1.0, 2.0, 5.0, 10.0];
/// Voltage steps: decoded against the tech node's range (Table 7).
const V_STEPS: usize = 8;
const GLB_RRAM_KB: [f64; 8] = [
    256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0, 12288.0, 16384.0,
];
/// SRAM designs swap weights through the GLB, so a wider range is explored
/// (paper §III-B).
const GLB_SRAM_KB: [f64; 12] = [
    512.0, 1024.0, 2048.0, 4096.0, 6144.0, 8192.0, 12288.0, 16384.0, 24576.0, 32768.0, 49152.0,
    65536.0,
];
const TECH_ALL_NM: [f64; 8] = [7.0, 10.0, 14.0, 22.0, 32.0, 45.0, 65.0, 90.0];

fn p(name: &'static str, values: &[f64]) -> ParamDef {
    ParamDef {
        name,
        values: values.to_vec(),
    }
}

impl SearchSpace {
    /// RRAM weight-stationary space at 32 nm (≈3.07×10⁶ points).
    pub fn rram() -> SearchSpace {
        SearchSpace {
            variant: "rram-32nm",
            params: vec![
                p("xbar_rows", &ROWS_COLS),
                p("xbar_cols", &ROWS_COLS),
                p("c_per_tile", &C_PER_TILE),
                p("t_per_router", &T_PER_ROUTER),
                p("g_per_chip", &G_PER_CHIP),
                p("bits_cell", &ALL_BITS_CELL),
                p("v_step", &steps(V_STEPS)),
                p("t_cycle_ns", &T_CYCLE_NS),
                p("glb_kb", &GLB_RRAM_KB),
                p("tech_nm", &[32.0]),
            ],
        }
    }

    /// SRAM weight-swapping space at 32 nm (≈1.54×10⁶ points).
    pub fn sram() -> SearchSpace {
        SearchSpace {
            variant: "sram-32nm",
            params: vec![
                p("xbar_rows", &ROWS_COLS),
                p("xbar_cols", &ROWS_COLS),
                p("c_per_tile", &C_PER_TILE),
                p("t_per_router", &T_PER_ROUTER),
                p("g_per_chip", &G_PER_CHIP),
                p("bits_cell", &[1.0]), // SRAM cells are 1-bit
                p("v_step", &steps(V_STEPS)),
                p("t_cycle_ns", &T_CYCLE_NS),
                p("glb_kb", &GLB_SRAM_KB),
                p("tech_nm", &[32.0]),
            ],
        }
    }

    /// SRAM space with the CMOS node as an optimization variable
    /// (paper §IV-I; ≈1.23×10⁷ points, the paper's 1.21×10⁷ band).
    pub fn sram_tech() -> SearchSpace {
        let mut s = SearchSpace::sram();
        s.variant = "sram-tech";
        s.params[idx::TECH_NM] = p("tech_nm", &TECH_ALL_NM);
        s
    }

    /// The reduced RRAM space of §III-C1 (Table 3): only crossbar rows,
    /// cols, macros-per-tile and bits-per-cell vary (768 points — small
    /// enough for exhaustive ground truth; denser row/col grids than the
    /// full space so the optimizer comparison is not trivially convex),
    /// remaining parameters pinned to mid-range defaults.
    pub fn rram_reduced() -> SearchSpace {
        SearchSpace {
            variant: "rram-reduced",
            params: vec![
                p("xbar_rows", &ALL_ROWS_COLS),
                p("xbar_cols", &ALL_ROWS_COLS),
                p("c_per_tile", &C_PER_TILE),
                p("t_per_router", &[8.0]),
                p("g_per_chip", &[24.0]),
                p("bits_cell", &ALL_BITS_CELL),
                p("v_step", &[4.0]),
                p("t_cycle_ns", &[2.0]),
                p("glb_kb", &[4096.0]),
                p("tech_nm", &[32.0]),
            ],
        }
    }

    /// Total number of design points (product of cardinalities).
    pub fn size(&self) -> u64 {
        self.params
            .iter()
            .map(|pd| pd.cardinality() as u64)
            .product()
    }

    /// Indices of parameters with more than one value (the free variables).
    pub fn free_params(&self) -> Vec<usize> {
        (0..NUM_PARAMS)
            .filter(|&i| self.params[i].cardinality() > 1)
            .collect()
    }

    /// Uniform random design.
    pub fn random(&self, rng: &mut Rng) -> Design {
        Design(
            self.params
                .iter()
                .map(|pd| rng.below(pd.cardinality()) as u16)
                .collect(),
        )
    }

    /// Decode a design into the canonical raw-value vector consumed by the
    /// evaluators. `v_step` decodes into volts against the design's tech
    /// node range.
    pub fn decode(&self, d: &Design) -> [f64; NUM_PARAMS] {
        let mut raw = [0.0; NUM_PARAMS];
        for i in 0..NUM_PARAMS {
            raw[i] = self.params[i].values[d.0[i] as usize];
        }
        let tech = raw[idx::TECH_NM];
        let (vmin, vmax) = voltage_range(tech);
        let step = raw[idx::V_STEP];
        raw[idx::V_STEP] = vmin + (vmax - vmin) * step / (V_STEPS as f64 - 1.0);
        raw
    }

    /// Number of voltage steps (for reporting).
    pub fn v_steps() -> usize {
        V_STEPS
    }

    /// Enumerate every design (mixed-radix counter). Only sensible for
    /// reduced spaces; asserts the size is small.
    pub fn enumerate(&self) -> Vec<Design> {
        let size = self.size();
        assert!(
            size <= 2_000_000,
            "refusing to enumerate {size} designs; use sampling"
        );
        let radixes: Vec<usize> = self.params.iter().map(|p| p.cardinality()).collect();
        let mut out = Vec::with_capacity(size as usize);
        let mut counter = vec![0u16; NUM_PARAMS];
        loop {
            out.push(Design(counter.clone()));
            // increment mixed-radix counter
            let mut i = NUM_PARAMS;
            loop {
                if i == 0 {
                    return out;
                }
                i -= 1;
                counter[i] += 1;
                if (counter[i] as usize) < radixes[i] {
                    break;
                }
                counter[i] = 0;
            }
        }
    }

    /// Index a design into a dense u64 (mixed-radix), used as a cache key.
    pub fn linear_index(&self, d: &Design) -> u64 {
        let mut acc = 0u64;
        for i in 0..NUM_PARAMS {
            acc = acc * self.params[i].cardinality() as u64 + d.0[i] as u64;
        }
        acc
    }

    /// Mutate one uniformly chosen free parameter to a new random index
    /// (used by the simple baselines; the GA uses SBX/polynomial ops).
    pub fn random_neighbor(&self, d: &Design, rng: &mut Rng) -> Design {
        let free = self.free_params();
        let mut out = d.clone();
        let pi = *rng.choose(&free);
        let card = self.params[pi].cardinality();
        if card > 1 {
            let mut nv = rng.below(card) as u16;
            while nv == d.0[pi] {
                nv = rng.below(card) as u16;
            }
            out.0[pi] = nv;
        }
        out
    }

    /// Snap a vector of *continuous* per-parameter positions (e.g. from
    /// SBX or PSO arithmetic) back onto valid indices.
    pub fn clamp_round(&self, xs: &[f64]) -> Design {
        Design(
            xs.iter()
                .enumerate()
                .map(|(i, &x)| {
                    let card = self.params[i].cardinality() as f64;
                    x.round().clamp(0.0, card - 1.0) as u16
                })
                .collect(),
        )
    }

    /// Human-readable summary of a design's decoded parameters.
    pub fn describe(&self, d: &Design) -> String {
        let raw = self.decode(d);
        format!(
            "R{rows}xC{cols} M{m} T{t} G{g} b{bits} V{v:.2} tc{tc}ns GLB{glb}KB {tech}nm",
            rows = raw[idx::ROWS],
            cols = raw[idx::COLS],
            m = raw[idx::C_PER_TILE],
            t = raw[idx::T_PER_ROUTER],
            g = raw[idx::G_PER_CHIP],
            bits = raw[idx::BITS_CELL],
            v = raw[idx::V_STEP],
            tc = raw[idx::T_CYCLE_NS],
            glb = raw[idx::GLB_KB],
            tech = raw[idx::TECH_NM],
        )
    }
}

fn steps(n: usize) -> Vec<f64> {
    (0..n).map(|i| i as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_sizes_match_paper_bands() {
        // Paper §III-B: 0.25e7 .. 1.21e7 depending on experiment.
        assert_eq!(SearchSpace::rram().size(), 3_072_000);
        assert_eq!(SearchSpace::sram().size(), 1_536_000);
        assert_eq!(SearchSpace::sram_tech().size(), 12_288_000);
        assert_eq!(SearchSpace::rram_reduced().size(), 768);
    }

    #[test]
    fn decode_voltage_against_tech() {
        let s = SearchSpace::rram();
        let mut d = s.random(&mut Rng::seed_from(1));
        d.0[idx::V_STEP] = 0;
        let lo = s.decode(&d)[idx::V_STEP];
        d.0[idx::V_STEP] = 7;
        let hi = s.decode(&d)[idx::V_STEP];
        // 32nm range is 0.65–1.0V (Table 7)
        assert!((lo - 0.65).abs() < 1e-9, "lo={lo}");
        assert!((hi - 1.0).abs() < 1e-9, "hi={hi}");
    }

    #[test]
    fn hamming_distance() {
        let a = Design(vec![0, 1, 2, 3, 0, 0, 0, 0, 0, 0]);
        let b = Design(vec![0, 1, 0, 3, 0, 0, 0, 0, 1, 0]);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.hamming(&a), 0);
        assert_eq!(a.hamming(&b), b.hamming(&a));
    }

    #[test]
    fn enumerate_reduced_space() {
        let s = SearchSpace::rram_reduced();
        let all = s.enumerate();
        assert_eq!(all.len(), 768);
        // all distinct
        let mut keys: Vec<u64> = all.iter().map(|d| s.linear_index(d)).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 768);
    }

    #[test]
    fn random_designs_valid() {
        let s = SearchSpace::sram_tech();
        let mut rng = Rng::seed_from(2);
        for _ in 0..1000 {
            let d = s.random(&mut rng);
            for (i, &v) in d.0.iter().enumerate() {
                assert!((v as usize) < s.params[i].cardinality());
            }
            let raw = s.decode(&d);
            assert!(raw[idx::ROWS] >= 32.0 && raw[idx::ROWS] <= 512.0);
            assert!(raw[idx::V_STEP] > 0.3 && raw[idx::V_STEP] < 1.4);
        }
    }

    #[test]
    fn neighbor_differs_in_one_param() {
        let s = SearchSpace::rram();
        let mut rng = Rng::seed_from(3);
        for _ in 0..200 {
            let d = s.random(&mut rng);
            let n = s.random_neighbor(&d, &mut rng);
            assert_eq!(d.hamming(&n), 1);
        }
    }

    #[test]
    fn clamp_round_snaps() {
        let s = SearchSpace::rram();
        let xs = vec![-1.0, 0.4, 0.6, 99.0, 2.2, 1.9, 3.5, 1.0, 2.0, 0.0];
        let d = s.clamp_round(&xs);
        assert_eq!(d.0[0], 0); // clamped below
        assert_eq!(d.0[1], 0); // rounds down
        assert_eq!(d.0[2], 1); // rounds up
        assert_eq!(d.0[3] as usize, s.params[3].cardinality() - 1); // clamped above
    }

    #[test]
    fn linear_index_bijective_on_reduced() {
        let s = SearchSpace::rram_reduced();
        let all = s.enumerate();
        for (i, d) in all.iter().enumerate() {
            // enumerate produces designs in mixed-radix ascending order
            assert_eq!(s.linear_index(d), i as u64);
        }
    }

    #[test]
    fn sequential_levels_cover_all_params() {
        use std::collections::HashSet;
        let lv: HashSet<_> = PARAM_LEVELS
            .iter()
            .map(|l| format!("{l:?}"))
            .collect();
        assert_eq!(lv.len(), 4);
    }
}
