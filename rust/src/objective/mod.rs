//! Joint objective functions across workloads (paper Eq. 3, §IV-C/H/I).
//!
//! A design is scored from its per-workload [`Metrics`] under an
//! aggregation scheme and an objective kind, subject to the area
//! constraint `A ≤ 800 mm²`; infeasible designs score `+∞`.
//! Energies/latencies are first converted to the paper's mJ/ms units so
//! reported scores carry the paper's mJ·ms·mm² EDAP scale.

use crate::model::{tech, Metrics};

/// Which metric product the objective minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjectiveKind {
    /// `agg(E) · agg(L) · A` — Eq. 3.
    Edap,
    /// `agg(E) · agg(L)`.
    Edp,
    /// `agg(E)`.
    Energy,
    /// `agg(L)`.
    Latency,
    /// `A` (area only).
    Area,
    /// `agg(E) · agg(L) · Cost`, `Cost = α(tech) · A` (§IV-I; area not
    /// double-counted since cost ∝ area).
    EdapCost,
    /// `agg(E) · agg(L) · A / Π accᵢ` (§IV-H).
    EdapAccuracy,
}

impl ObjectiveKind {
    pub fn name(&self) -> &'static str {
        match self {
            ObjectiveKind::Edap => "EDAP",
            ObjectiveKind::Edp => "EDP",
            ObjectiveKind::Energy => "Energy",
            ObjectiveKind::Latency => "Latency",
            ObjectiveKind::Area => "Area",
            ObjectiveKind::EdapCost => "EDAP-Cost",
            ObjectiveKind::EdapAccuracy => "EDAP/Acc",
        }
    }
}

/// Cross-workload aggregation scheme (§IV-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Aggregation {
    /// `max` over workloads (Eq. 3 default).
    Max,
    /// Product over all workloads ("All": `E_w-all = Π E_wi`).
    All,
    /// Arithmetic mean (used in the 9-workload experiment, §IV-J).
    Mean,
}

impl Aggregation {
    pub fn name(&self) -> &'static str {
        match self {
            Aggregation::Max => "Max",
            Aggregation::All => "All",
            Aggregation::Mean => "Mean",
        }
    }

    /// Aggregate a slice (reporting paths); the scoring hot path streams
    /// through `init`/`accumulate`/`finish` directly to avoid temporaries.
    pub fn apply(&self, xs: &[f64]) -> f64 {
        let mut acc = self.init();
        for &x in xs {
            acc = self.accumulate(acc, x);
        }
        self.finish(acc, xs.len())
    }

    /// Streaming aggregation (identity / accumulate / finalize), so the
    /// hot scoring path folds unit conversion into one loop instead of
    /// materializing per-workload `Vec`s. Matches the batch [`Self::apply`]
    /// bit-for-bit: same fold order, same NaN handling as `stats::max`.
    fn init(&self) -> f64 {
        match self {
            Aggregation::Max => f64::NEG_INFINITY,
            Aggregation::All => 1.0,
            Aggregation::Mean => 0.0,
        }
    }

    fn accumulate(&self, acc: f64, x: f64) -> f64 {
        match self {
            // f64::max ignores a NaN operand, like `stats::max`'s filter
            Aggregation::Max => acc.max(x),
            Aggregation::All => acc * x,
            Aggregation::Mean => acc + x,
        }
    }

    fn finish(&self, acc: f64, n: usize) -> f64 {
        match self {
            Aggregation::Max | Aggregation::All => acc,
            Aggregation::Mean => {
                if n == 0 {
                    0.0
                } else {
                    acc / n as f64
                }
            }
        }
    }
}

/// A complete scoring configuration.
#[derive(Clone, Copy, Debug)]
pub struct Objective {
    pub kind: ObjectiveKind,
    pub agg: Aggregation,
    /// Area constraint (mm²), `A_constr` in the paper.
    pub area_constraint: f64,
}

impl Objective {
    pub fn new(kind: ObjectiveKind, agg: Aggregation) -> Objective {
        Objective {
            kind,
            agg,
            area_constraint: crate::model::consts::AREA_CONSTR_MM2,
        }
    }

    /// Eq. 3 default: `max(E)·max(L)·A`.
    pub fn edap() -> Objective {
        Objective::new(ObjectiveKind::Edap, Aggregation::Max)
    }

    pub fn name(&self) -> String {
        format!("{}/{}", self.kind.name(), self.agg.name())
    }

    /// Score a design from its per-workload metrics. `accuracies` is only
    /// consulted by [`ObjectiveKind::EdapAccuracy`]; `tech_nm` only by
    /// [`ObjectiveKind::EdapCost`]. Lower is better; infeasible → `+∞`.
    pub fn score(
        &self,
        per_workload: &[Metrics],
        accuracies: Option<&[f64]>,
        tech_nm: f64,
    ) -> f64 {
        assert!(!per_workload.is_empty());
        if per_workload.iter().any(|m| !m.feasible) {
            return f64::INFINITY;
        }
        let area = per_workload[0].area;
        if area > self.area_constraint {
            return f64::INFINITY;
        }
        // paper units: mJ / ms — unit conversion folded into one
        // allocation-free aggregation pass (this runs once per evaluated
        // design on the search hot path)
        let mut acc_e = self.agg.init();
        let mut acc_l = self.agg.init();
        for m in per_workload {
            acc_e = self.agg.accumulate(acc_e, m.energy * 1e3);
            acc_l = self.agg.accumulate(acc_l, m.latency * 1e3);
        }
        let ae = self.agg.finish(acc_e, per_workload.len());
        let al = self.agg.finish(acc_l, per_workload.len());
        match self.kind {
            ObjectiveKind::Edap => ae * al * area,
            ObjectiveKind::Edp => ae * al,
            ObjectiveKind::Energy => ae,
            ObjectiveKind::Latency => al,
            ObjectiveKind::Area => area,
            ObjectiveKind::EdapCost => ae * al * tech::fabrication_cost(tech_nm, area),
            ObjectiveKind::EdapAccuracy => {
                let accs = accuracies.expect("EdapAccuracy requires accuracies");
                assert_eq!(accs.len(), per_workload.len());
                let prod: f64 = accs.iter().product();
                ae * al * area / prod.max(1e-6)
            }
        }
    }

    /// Per-workload score of a single workload on a (jointly chosen)
    /// design — the quantity plotted in Fig. 5 (`E_wi · L_wi · A` etc.).
    pub fn single_workload_score(&self, m: &Metrics, tech_nm: f64) -> f64 {
        self.score(std::slice::from_ref(m), Some(&[1.0]), tech_nm)
    }

    /// The four objective settings of Fig. 5 / Fig. 6 panels.
    pub fn figure5_set() -> Vec<Objective> {
        vec![
            Objective::new(ObjectiveKind::Edap, Aggregation::Max),
            Objective::new(ObjectiveKind::Edp, Aggregation::Max),
            Objective::new(ObjectiveKind::Energy, Aggregation::Max),
            Objective::new(ObjectiveKind::Latency, Aggregation::Max),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(e_mj: f64, l_ms: f64, a: f64) -> Metrics {
        Metrics {
            energy: e_mj * 1e-3,
            latency: l_ms * 1e-3,
            area: a,
            feasible: true,
        }
    }

    #[test]
    fn edap_max_matches_eq3() {
        let obj = Objective::edap();
        let ms = [m(1.0, 2.0, 50.0), m(3.0, 1.0, 50.0)];
        // max(E)=3, max(L)=2, A=50 -> 300
        assert!((obj.score(&ms, None, 32.0) - 300.0).abs() < 1e-9);
    }

    #[test]
    fn all_aggregation_is_product() {
        let obj = Objective::new(ObjectiveKind::Edp, Aggregation::All);
        let ms = [m(2.0, 3.0, 10.0), m(4.0, 5.0, 10.0)];
        // (2*4) * (3*5) = 120
        assert!((obj.score(&ms, None, 32.0) - 120.0).abs() < 1e-9);
    }

    #[test]
    fn mean_aggregation() {
        let obj = Objective::new(ObjectiveKind::Energy, Aggregation::Mean);
        let ms = [m(2.0, 1.0, 10.0), m(4.0, 1.0, 10.0)];
        assert!((obj.score(&ms, None, 32.0) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_scores_infinity() {
        let obj = Objective::edap();
        let mut bad = m(1.0, 1.0, 10.0);
        bad.feasible = false;
        assert!(obj.score(&[bad], None, 32.0).is_infinite());
        // area constraint violation
        let big = m(1.0, 1.0, 900.0);
        assert!(obj.score(&[big], None, 32.0).is_infinite());
    }

    #[test]
    fn cost_objective_uses_alpha() {
        let obj = Objective::new(ObjectiveKind::EdapCost, Aggregation::Max);
        let ms = [m(1.0, 1.0, 100.0)];
        let at32 = obj.score(&ms, None, 32.0);
        let at7 = obj.score(&ms, None, 7.0);
        assert!((at32 - 100.0).abs() < 1e-9);
        assert!((at7 / at32 - 3.871).abs() < 1e-6); // α(7nm)
    }

    #[test]
    fn accuracy_objective_divides() {
        let obj = Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max);
        let ms = [m(1.0, 1.0, 10.0), m(1.0, 1.0, 10.0)];
        let hi = obj.score(&ms, Some(&[0.9, 0.9]), 32.0);
        let lo = obj.score(&ms, Some(&[0.5, 0.5]), 32.0);
        assert!(lo > hi); // lower accuracy -> worse (higher) score
    }

    #[test]
    fn streaming_aggregation_matches_batch_semantics() {
        let xs = [2.0, 8.0, 4.0, 1.0];
        assert_eq!(Aggregation::Max.apply(&xs).to_bits(), 8.0f64.to_bits());
        assert_eq!(Aggregation::All.apply(&xs).to_bits(), 64.0f64.to_bits());
        assert_eq!(
            Aggregation::Mean.apply(&xs).to_bits(),
            (xs.iter().sum::<f64>() / 4.0).to_bits()
        );
        // NaN handling mirrors stats::max (NaN operands are ignored)
        assert_eq!(Aggregation::Max.apply(&[f64::NAN, 3.0, 1.0]), 3.0);
    }

    #[test]
    fn single_workload_score_matches_joint_of_one() {
        let obj = Objective::edap();
        let x = m(2.0, 3.0, 10.0);
        assert_eq!(obj.single_workload_score(&x, 32.0), 60.0);
    }
}
