//! Scenario portfolios: train/deploy generalization studies over the
//! paper's workload sets.
//!
//! The paper's headline claim is that **one** jointly-optimized IMC design
//! serves many workloads with near-specialized EDAP. The `genmatrix`
//! experiment probes that claim hold-*one*-out; this module generalizes it
//! to arbitrary **portfolios** — a [`Portfolio`] names the workload subset
//! a joint search optimizes (*train*) and the subset the resulting design
//! is scored on after deployment (*deploy*). Combinatorial generators
//! produce the standard study shapes:
//!
//! * [`hold_k_out`] — every `k`-combination of a set is held out and the
//!   joint search runs on the remaining `N − k` workloads (the
//!   `genmatrix_k` experiment; `k = 1` reproduces `genmatrix` exactly,
//!   down to the RNG streams — see [`Portfolio::joint_seed`]).
//! * [`transfer_portfolios`] — cross-set transfer over the 9-workload set
//!   (the `transfer` experiment): optimize on the cnn4 subset and deploy
//!   on the five extra workloads, and the all-9 joint reference deployed
//!   per workload.
//!
//! Deploy-side scoring is always the *generalization gap*: the joint
//! design's per-workload EDAP divided by that workload's separate-search
//! bound (a specialist optimized for it alone). [`gap`] and
//! [`GapSummary`] centralize the arithmetic so every experiment reports
//! the same quantity; the per-workload bounds themselves are computed
//! once per experiment and memoized through the checkpoint layer
//! (`experiments::common::separate_bound_cell`).
//!
//! Everything here is pure data + combinatorics — no evaluator, no
//! checkpoint I/O — so portfolios are cheap to construct in tests and
//! doctests. The experiment-side plumbing (journaled cells, JSON
//! artifacts) lives in `experiments::common`.

use crate::model::MemoryTech;
use crate::objective::{Aggregation, Objective, ObjectiveKind};
use crate::robustness::Corner;
use crate::space::SearchSpace;
use crate::util::stats;
use crate::workloads::WorkloadSet;

/// Radix of [`Portfolio::seed_tag`]: deploy indices are digits of a
/// base-64 number, prefixed by a size-dependent base so deploy sets of
/// different sizes land in disjoint tag ranges. A singleton `[w]` gets
/// tag `w` — the property that makes `genmatrix_k`'s `k = 1` slice
/// bit-identical to `genmatrix`.
const SEED_RADIX: u64 = 64;

/// One scenario family: a named workload set bound to the memory
/// technology, search space and aggregation the paper evaluates it under.
///
/// The two paper instances ([`ScenarioSpec::cnn4`] on weight-stationary
/// RRAM with Max aggregation, [`ScenarioSpec::all9`] on weight-swapping
/// SRAM with Mean aggregation, §IV-J) are single-sourced here so
/// `genmatrix`, `genmatrix_k`, `transfer` and `pareto` cannot drift
/// apart; [`ScenarioSpec::parse`] opens user-defined families from the
/// CLI (`--spec`).
pub struct ScenarioSpec {
    /// Stable set name ("cnn4" / "all9" / "custom" for `--spec`): cell
    /// keys and artifact stems.
    pub name: String,
    /// The workload set itself.
    pub set: WorkloadSet,
    /// Search space matching the memory technology.
    pub space: SearchSpace,
    /// Memory technology the designs are evaluated on.
    pub mem: MemoryTech,
    /// Cross-workload aggregation of the joint objective.
    pub agg: Aggregation,
    /// Device-variation corner the scenario is evaluated at (the
    /// noise-sweep family: `--spec …:<low|nominal|high>`). Pinning a
    /// corner switches the joint objective to accuracy-aware EDAP and
    /// requires every workload to carry a Fig. 8 accuracy baseline;
    /// `None` (all built-in specs) reproduces the paper setup exactly.
    pub corner: Option<Corner>,
}

impl ScenarioSpec {
    /// The paper's core 4-workload CNN set on weight-stationary RRAM,
    /// Max-aggregated EDAP.
    pub fn cnn4() -> ScenarioSpec {
        ScenarioSpec {
            name: "cnn4".into(),
            set: WorkloadSet::cnn4(),
            space: SearchSpace::rram(),
            mem: MemoryTech::Rram,
            agg: Aggregation::Max,
            corner: None,
        }
    }

    /// The 9-workload scalability set on weight-swapping SRAM, Mean
    /// aggregation (§IV-J, as in Fig. 10, so GPT-2 Medium does not
    /// dominate the joint score).
    pub fn all9() -> ScenarioSpec {
        ScenarioSpec {
            name: "all9".into(),
            set: WorkloadSet::all9(),
            space: SearchSpace::sram(),
            mem: MemoryTech::Sram,
            agg: Aggregation::Mean,
            corner: None,
        }
    }

    /// The 9-workload set on weight-stationary RRAM (Max aggregation, the
    /// RRAM convention). Not a paper scenario: GPT-2 Medium cannot fit a
    /// weight-stationary chip, so deployments on it are infeasible by
    /// construction — the `transfer` experiment uses this family to report
    /// that capacity failure as an explicit infeasibility rate instead of
    /// dropping the row.
    pub fn all9_rram() -> ScenarioSpec {
        ScenarioSpec {
            name: "all9-rram".into(),
            set: WorkloadSet::all9(),
            space: SearchSpace::rram(),
            mem: MemoryTech::Rram,
            agg: Aggregation::Max,
            corner: None,
        }
    }

    /// Parse a user-defined scenario family from a `--spec` string:
    /// `<w1>+<w2>+...:<mem>[:<agg>][:<corner>]`, e.g.
    /// `resnet18+vit+gpt2-medium:sram:mean` or
    /// `resnet18+alexnet:rram:high`. Workload tokens are the canonical
    /// names of [`crate::workloads::ALL_NAMES`] **or file paths**
    /// (anything with a `/` or a `.json`/`.onnx` extension, read through
    /// [`crate::ingest::load_path`]); `mem` is `rram` | `sram` (choosing
    /// the matching search space), and the optional aggregation
    /// (`max` | `all` | `mean`) defaults to the paper convention for the
    /// technology (RRAM → Max, SRAM → Mean). An optional
    /// device-variation corner (`low` | `nominal` | `high`, in either
    /// trailing position) pins the accuracy model to that operating
    /// point and switches the objective to accuracy-aware EDAP — the
    /// noise-sweep scenario family; every workload must then carry a
    /// Fig. 8 accuracy baseline. The resulting spec is named `custom`;
    /// the checkpoint configuration fingerprint pins the full `--spec`
    /// string, so journals from different custom families never mix.
    ///
    /// The whole string may instead be a synthetic-population token,
    /// `synth:<dist>:<n>:<seed>[:<mem>][:<agg>][:<corner>]` with `dist`
    /// in `cnn` | `transformer` | `mixed` (mem defaults to `rram`):
    /// member `i` is a pure function of `(dist, seed, i)` (see
    /// [`crate::ingest::WorkloadDistribution`]), so the family is
    /// bit-identical across threads, workers and resume. Synthetic specs
    /// are named `synth-<dist><n>-s<seed>`, keeping shared checkpoint
    /// namespaces from colliding across families.
    ///
    /// ```
    /// use imcopt::scenarios::ScenarioSpec;
    ///
    /// let spec = ScenarioSpec::parse("resnet18+vgg16:rram").unwrap();
    /// assert_eq!(spec.name, "custom");
    /// assert_eq!(spec.set.len(), 2);
    /// assert!(ScenarioSpec::parse("resnet34:rram").is_err());
    /// let sweep = ScenarioSpec::parse("resnet18+vgg16:rram:high").unwrap();
    /// assert!(sweep.corner.is_some());
    /// let synth = ScenarioSpec::parse("synth:mixed:20:7:sram").unwrap();
    /// assert_eq!(synth.name, "synth-mixed20-s7");
    /// assert_eq!(synth.set.len(), 20);
    /// ```
    pub fn parse(spec: &str) -> anyhow::Result<ScenarioSpec> {
        let parts: Vec<&str> = spec.split(':').collect();
        // synthetic-population token: synth:<dist>:<n>:<seed>[:...]
        let (name, set, tail) = if parts[0] == "synth" {
            anyhow::ensure!(
                (4..=7).contains(&parts.len()),
                "--spec wants 'synth:<dist>:<n>:<seed>[:<mem>][:<agg>][:<corner>]', got '{spec}'"
            );
            let (dist, n, seed) =
                crate::ingest::synth::parse_synth_parts(parts[1], parts[2], parts[3])
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
            let name = format!("synth-{}{n}-s{seed}", dist.id);
            (name, dist.population(n, seed), &parts[4..])
        } else {
            anyhow::ensure!(
                (2..=4).contains(&parts.len()),
                "--spec wants '<w1>+<w2>+...:<mem>[:<agg>][:<corner>]', got '{spec}'"
            );
            let tokens: Vec<&str> = parts[0]
                .split('+')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .collect();
            anyhow::ensure!(!tokens.is_empty(), "--spec lists no workloads: '{spec}'");
            let mut workloads = Vec::new();
            for t in tokens {
                if crate::ingest::looks_like_path(t) {
                    workloads.push(
                        crate::ingest::load_path(std::path::Path::new(t))
                            .map_err(|e| anyhow::anyhow!("{e}"))?,
                    );
                } else {
                    workloads.push(crate::workloads::by_name(t)?);
                }
            }
            ("custom".to_string(), WorkloadSet { workloads }, &parts[1..])
        };
        let (mem, space) = match tail.first().copied().unwrap_or("rram") {
            "rram" => (MemoryTech::Rram, SearchSpace::rram()),
            "sram" => (MemoryTech::Sram, SearchSpace::sram()),
            other => anyhow::bail!("--spec memory '{other}' is not rram|sram"),
        };
        let mut agg: Option<Aggregation> = None;
        let mut corner: Option<Corner> = None;
        for token in tail.iter().skip(1) {
            let parsed_agg = match *token {
                "max" => Some(Aggregation::Max),
                "all" => Some(Aggregation::All),
                "mean" => Some(Aggregation::Mean),
                _ => None,
            };
            if let Some(a) = parsed_agg {
                anyhow::ensure!(
                    agg.is_none(),
                    "--spec repeats the aggregation: '{spec}'"
                );
                agg = Some(a);
            } else if let Some(c) = Corner::parse(token) {
                anyhow::ensure!(corner.is_none(), "--spec repeats the corner: '{spec}'");
                corner = Some(c);
            } else {
                anyhow::bail!(
                    "--spec token '{token}' is neither an aggregation (max|all|mean) \
                     nor a corner (low|nominal|high)"
                );
            }
        }
        if corner.is_some() {
            for w in &set.workloads {
                anyhow::ensure!(
                    crate::accuracy::has_baseline(&w.name),
                    "--spec corner scenarios score accuracy, but workload '{}' has \
                     no accuracy baseline",
                    w.name
                );
            }
        }
        let agg = agg.unwrap_or(match mem {
            MemoryTech::Rram => Aggregation::Max,
            MemoryTech::Sram => Aggregation::Mean,
        });
        Ok(ScenarioSpec {
            name,
            set,
            space,
            mem,
            agg,
            corner,
        })
    }

    /// The joint objective this scenario optimizes: EDAP under the
    /// scenario's aggregation, accuracy-aware when a corner is pinned.
    pub fn objective(&self) -> Objective {
        let kind = if self.corner.is_some() {
            ObjectiveKind::EdapAccuracy
        } else {
            ObjectiveKind::Edap
        };
        Objective::new(kind, self.agg)
    }
}

/// Both paper scenario families, in report order.
pub fn paper_specs() -> Vec<ScenarioSpec> {
    vec![ScenarioSpec::cnn4(), ScenarioSpec::all9()]
}

/// A generalization scenario: optimize jointly on `train`, score on
/// `deploy`. Indices refer to one [`ScenarioSpec`]'s workload set; both
/// lists are kept sorted and deduplicated so equal portfolios compare
/// equal and produce equal cache/journal keys.
///
/// ```
/// use imcopt::scenarios::{hold_k_out, Portfolio};
///
/// // Every hold-2-out split of a 4-workload set: C(4, 2) = 6 portfolios,
/// // each training on the complement of its deploy pair.
/// let ports = hold_k_out(4, 2);
/// assert_eq!(ports.len(), 6);
/// assert_eq!(ports[0].deploy, vec![0, 1]);
/// assert_eq!(ports[0].train, vec![2, 3]);
///
/// // Hand-built portfolios normalize their index lists.
/// let p = Portfolio::new("demo", vec![3, 1, 3], vec![0]);
/// assert_eq!(p.train, vec![1, 3]);
/// assert_eq!(p.seed_tag(), 0); // singleton deploy [w] tags as w
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Portfolio {
    /// Stable identifier: journal-cell keys and artifact file stems
    /// (unique within one experiment).
    pub id: String,
    /// Workload indices the joint search optimizes.
    pub train: Vec<usize>,
    /// Workload indices the chosen design is scored on after deployment.
    pub deploy: Vec<usize>,
}

impl Portfolio {
    /// Build a portfolio, normalizing (sorting + deduplicating) both
    /// index lists. Panics if either side ends up empty — a portfolio
    /// must train on something and deploy somewhere.
    pub fn new(id: impl Into<String>, mut train: Vec<usize>, mut deploy: Vec<usize>) -> Portfolio {
        train.sort_unstable();
        train.dedup();
        deploy.sort_unstable();
        deploy.dedup();
        assert!(!train.is_empty(), "portfolio must train on >= 1 workload");
        assert!(!deploy.is_empty(), "portfolio must deploy on >= 1 workload");
        Portfolio {
            id: id.into(),
            train,
            deploy,
        }
    }

    /// Number of held-out (deployed) workloads.
    pub fn k(&self) -> usize {
        self.deploy.len()
    }

    /// Deterministic tag of the deploy set: its indices read as base-64
    /// digits on top of a size-dependent base (`0, 64, 64 + 64², ...`),
    /// so deploy sets of different sizes cannot collide (e.g. `[0, 1]`
    /// vs `[1]`; distinct for sets with indices < 64 and size ≤ 5 — the
    /// u64 wraps beyond that, which can only repeat a seed, never
    /// corrupt a result). The base for size 1 is 0, so a singleton `[w]`
    /// tags as `w` — which keeps `genmatrix_k`'s `k = 1` RNG streams
    /// identical to `genmatrix`'s.
    pub fn seed_tag(&self) -> u64 {
        let mut base = 0u64;
        for m in 1..self.deploy.len() {
            base = base.wrapping_add(SEED_RADIX.wrapping_pow(m as u32));
        }
        self.deploy
            .iter()
            .fold(base, |acc, &i| acc.wrapping_mul(SEED_RADIX).wrapping_add(i as u64))
    }

    /// Seed of this portfolio's joint search, derived from the experiment
    /// seed (`base + tag·7919`, the scheme `genmatrix` uses per held-out
    /// workload).
    pub fn joint_seed(&self, base: u64) -> u64 {
        base.wrapping_add(self.seed_tag().wrapping_mul(7919))
    }

    /// Workload names of an index list, resolved against the scenario's
    /// set (helper for reports and artifacts).
    pub fn names<'a>(indices: &[usize], set: &'a WorkloadSet) -> Vec<&'a str> {
        indices.iter().map(|&i| set.workloads[i].name.as_str()).collect()
    }
}

/// Seed of the separate-search (specialist) bound for workload `wi` —
/// salted like `genmatrix`'s per-workload specialist runs so the RNG
/// streams differ from every joint search.
pub fn bound_seed(base: u64, wi: usize) -> u64 {
    base.wrapping_mul(31).wrapping_add(wi as u64 * 1009)
}

/// All `k`-combinations of `0..n` in lexicographic order.
pub fn combinations(n: usize, k: usize) -> Vec<Vec<usize>> {
    assert!(k >= 1 && k <= n, "need 1 <= k <= n (got k={k}, n={n})");
    let mut out = Vec::new();
    let mut cur: Vec<usize> = (0..k).collect();
    loop {
        out.push(cur.clone());
        // advance the rightmost digit that can still move
        let mut i = k;
        while i > 0 {
            i -= 1;
            if cur[i] < n - (k - i) {
                cur[i] += 1;
                for j in i + 1..k {
                    cur[j] = cur[j - 1] + 1;
                }
                break;
            }
            if i == 0 {
                return out;
            }
        }
    }
}

/// Indices of `0..n` not in `subset` (which must be sorted).
pub fn complement(n: usize, subset: &[usize]) -> Vec<usize> {
    (0..n).filter(|i| !subset.contains(i)).collect()
}

/// Every hold-`k`-out portfolio of an `n`-workload set: each
/// `k`-combination is deployed on while the complement is trained on.
/// Ids are `k<k>-<i>+<j>+...` over the deploy indices.
pub fn hold_k_out(n: usize, k: usize) -> Vec<Portfolio> {
    assert!(k >= 1 && k < n, "hold-k-out needs 1 <= k < n (got k={k}, n={n})");
    combinations(n, k)
        .into_iter()
        .map(|deploy| {
            let id = format!(
                "k{k}-{}",
                deploy
                    .iter()
                    .map(|i| i.to_string())
                    .collect::<Vec<_>>()
                    .join("+")
            );
            let train = complement(n, &deploy);
            Portfolio::new(id, train, deploy)
        })
        .collect()
}

/// The cross-set transfer portfolios of the `transfer` experiment, all
/// over the 9-workload set ([`ScenarioSpec::all9`]; its first four
/// workloads are exactly the cnn4 set):
///
/// * `cnn4-to-extras` — optimize on the cnn4 subset, deploy on the five
///   extra workloads (pure transfer: nothing deployed was trained on).
/// * `cnn4-to-all9` — the same design scored on the full set (how much
///   headroom the cnn4-trained design leaves on its own training set vs
///   the extras).
/// * `all9-joint` — the all-9 joint reference deployed per workload (the
///   paper's 9-workload generalization row, as a portfolio).
pub fn transfer_portfolios() -> Vec<Portfolio> {
    vec![
        Portfolio::new("cnn4-to-extras", (0..4).collect(), (4..9).collect()),
        Portfolio::new("cnn4-to-all9", (0..4).collect(), (0..9).collect()),
        Portfolio::new("all9-joint", (0..9).collect(), (0..9).collect()),
    ]
}

/// The weight-stationary companion row of [`transfer_portfolios`]: the
/// cnn4-trained design deployed on the all9 extras under
/// [`ScenarioSpec::all9_rram`]. GPT-2 Medium is infeasible on a
/// weight-stationary chip, so this row exercises the deploy-side
/// infeasibility-rate reporting (`common::infeasible_rate`).
pub fn rram_transfer_portfolios() -> Vec<Portfolio> {
    vec![Portfolio::new(
        "cnn4-to-extras-rram",
        (0..4).collect(),
        (4..9).collect(),
    )]
}

/// The [`transfer_portfolios`] shape over an arbitrary `n`-workload set,
/// split at `split`: train on the first `split` workloads, deploy on the
/// extras / the full set, plus the all-`n` joint reference. Used by the
/// `transfer` experiment under a `--spec` scenario (the paper's all9
/// case is the `n = 9, split = 4` instance, under its own ids).
pub fn split_transfer_portfolios(n: usize, split: usize) -> Vec<Portfolio> {
    assert!(
        split >= 1 && split < n,
        "transfer split needs 1 <= split < n (got split={split}, n={n})"
    );
    vec![
        Portfolio::new(
            format!("head{split}-to-extras"),
            (0..split).collect(),
            (split..n).collect(),
        ),
        Portfolio::new(
            format!("head{split}-to-all"),
            (0..split).collect(),
            (0..n).collect(),
        ),
        Portfolio::new("all-joint", (0..n).collect(), (0..n).collect()),
    ]
}

/// Deploy-side generalization gap: the joint design's EDAP on a workload
/// over the specialist bound for that workload. `1.0` = the joint design
/// matches the specialist; `NaN` when the bound is non-positive or
/// non-finite (no feasible specialist to compare against).
pub fn gap(joint: f64, bound: f64) -> f64 {
    if bound > 0.0 && bound.is_finite() {
        joint / bound
    } else {
        f64::NAN
    }
}

/// Aggregate view of a list of per-workload gaps (NaN/inf entries are
/// excluded from the means but counted against `total`).
#[derive(Clone, Copy, Debug)]
pub struct GapSummary {
    /// Arithmetic mean of the finite gaps (0 when none are finite).
    pub mean: f64,
    /// Geometric mean of the finite gaps (0 when none are finite).
    pub geo_mean: f64,
    /// Largest finite gap (−inf when none are finite).
    pub worst: f64,
    /// Position of the worst finite gap in the input slice.
    pub worst_at: Option<usize>,
    /// Finite gaps observed.
    pub finite: usize,
    /// Total gaps observed (finite or not).
    pub total: usize,
}

/// Summarize a gap list (see [`GapSummary`]).
pub fn summarize_gaps(gaps: &[f64]) -> GapSummary {
    let finite: Vec<f64> = gaps.iter().copied().filter(|g| g.is_finite()).collect();
    let mut worst = f64::NEG_INFINITY;
    let mut worst_at = None;
    for (i, &g) in gaps.iter().enumerate() {
        if g.is_finite() && g > worst {
            worst = g;
            worst_at = Some(i);
        }
    }
    GapSummary {
        mean: stats::mean(&finite),
        geo_mean: stats::geo_mean(&finite),
        worst,
        worst_at,
        finite: finite.len(),
        total: gaps.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combinations_counts_match_binomials() {
        assert_eq!(combinations(4, 1).len(), 4);
        assert_eq!(combinations(4, 2).len(), 6);
        assert_eq!(combinations(4, 3).len(), 4);
        assert_eq!(combinations(9, 2).len(), 36);
        assert_eq!(combinations(9, 3).len(), 84);
        assert_eq!(combinations(3, 3), vec![vec![0, 1, 2]]);
        // lexicographic, all distinct
        let cs = combinations(5, 2);
        for w in cs.windows(2) {
            assert!(w[0] < w[1], "{w:?} out of order");
        }
    }

    #[test]
    fn complement_partitions_the_index_range() {
        assert_eq!(complement(4, &[1, 3]), vec![0, 2]);
        assert_eq!(complement(3, &[0, 1, 2]), Vec::<usize>::new());
        for c in combinations(6, 2) {
            let mut both = c.clone();
            both.extend(complement(6, &c));
            both.sort_unstable();
            assert_eq!(both, (0..6).collect::<Vec<_>>());
        }
    }

    #[test]
    fn hold_k_out_trains_on_the_complement() {
        let ports = hold_k_out(4, 1);
        assert_eq!(ports.len(), 4);
        for (wi, p) in ports.iter().enumerate() {
            assert_eq!(p.deploy, vec![wi]);
            assert_eq!(p.train, complement(4, &[wi]));
            assert_eq!(p.k(), 1);
            // singleton seed tag is the index itself -> genmatrix streams
            assert_eq!(p.seed_tag(), wi as u64);
            assert_eq!(p.joint_seed(47), 47u64.wrapping_add(wi as u64 * 7919));
        }
        let k3 = hold_k_out(9, 3);
        let ids: std::collections::BTreeSet<&str> =
            k3.iter().map(|p| p.id.as_str()).collect();
        assert_eq!(ids.len(), 84, "ids must be unique");
    }

    #[test]
    fn seed_tags_distinguish_deploy_sets() {
        let mut seen = std::collections::BTreeSet::new();
        for k in 1..=3 {
            for p in hold_k_out(9, k) {
                assert!(seen.insert(p.seed_tag()), "tag collision at {:?}", p.deploy);
            }
        }
    }

    #[test]
    fn bound_seed_matches_genmatrix_scheme() {
        assert_eq!(bound_seed(47, 3), 47u64.wrapping_mul(31).wrapping_add(3 * 1009));
    }

    #[test]
    fn transfer_portfolios_cover_the_all9_split() {
        let ports = transfer_portfolios();
        assert_eq!(ports.len(), 3);
        let extras = &ports[0];
        assert_eq!(extras.id, "cnn4-to-extras");
        assert_eq!(extras.train, vec![0, 1, 2, 3]);
        assert_eq!(extras.deploy, vec![4, 5, 6, 7, 8]);
        // the first four all9 workloads are exactly the cnn4 set — the
        // transfer indices rely on it
        let cnn4 = WorkloadSet::cnn4();
        let all9 = WorkloadSet::all9();
        for (i, w) in cnn4.workloads.iter().enumerate() {
            assert_eq!(w.name, all9.workloads[i].name);
        }
        let ids: std::collections::BTreeSet<&str> =
            ports.iter().map(|p| p.id.as_str()).collect();
        assert_eq!(ids.len(), ports.len());
    }

    #[test]
    fn gap_and_summary_handle_non_finite_bounds() {
        assert_eq!(gap(2.0, 1.0), 2.0);
        assert!(gap(1.0, 0.0).is_nan());
        assert!(gap(1.0, f64::INFINITY).is_nan());
        assert!(gap(f64::INFINITY, 1.0).is_infinite());
        let s = summarize_gaps(&[1.5, f64::NAN, 0.5, f64::INFINITY]);
        assert_eq!(s.finite, 2);
        assert_eq!(s.total, 4);
        assert_eq!(s.worst, 1.5);
        assert_eq!(s.worst_at, Some(0));
        assert!((s.mean - 1.0).abs() < 1e-12);
        let empty = summarize_gaps(&[f64::NAN]);
        assert_eq!(empty.finite, 0);
        assert!(empty.worst_at.is_none());
    }

    #[test]
    fn split_transfer_matches_the_all9_shape() {
        let generic = split_transfer_portfolios(9, 4);
        let paper = transfer_portfolios();
        assert_eq!(generic.len(), paper.len());
        for (g, p) in generic.iter().zip(&paper) {
            assert_eq!(g.train, p.train);
            assert_eq!(g.deploy, p.deploy);
        }
        assert_eq!(generic[0].id, "head4-to-extras");
        let small = split_transfer_portfolios(3, 1);
        assert_eq!(small[0].train, vec![0]);
        assert_eq!(small[0].deploy, vec![1, 2]);
        assert_eq!(small[2].id, "all-joint");
    }

    #[test]
    fn spec_parse_accepts_and_rejects() {
        let s = ScenarioSpec::parse("resnet18+vgg16+vit:sram").unwrap();
        assert_eq!(s.name, "custom");
        assert_eq!(s.set.names(), vec!["resnet18", "vgg16", "vit"]);
        assert_eq!(s.mem, MemoryTech::Sram);
        assert_eq!(s.agg, Aggregation::Mean, "SRAM defaults to Mean");
        assert_eq!(s.space.variant, "sram-32nm");
        let r = ScenarioSpec::parse("alexnet:rram:all").unwrap();
        assert_eq!(r.agg, Aggregation::All);
        assert_eq!(r.mem, MemoryTech::Rram);
        assert_eq!(
            ScenarioSpec::parse("alexnet:rram").unwrap().agg,
            Aggregation::Max,
            "RRAM defaults to Max"
        );
        for bad in [
            "alexnet",              // no memory tech
            "alexnet:dram",         // unknown tech
            "alexnet:rram:median",  // unknown aggregation/corner
            ":rram",                // empty workload list
            "resnet34:rram",        // unknown workload
            "a:b:c:d",              // unknown workload with full syntax
            "a:b:c:d:e",            // too many parts
            "alexnet:rram:max:all", // two aggregations
            "alexnet:rram:low:high", // two corners
            "vit:rram:high",        // corner without an accuracy baseline
        ] {
            assert!(ScenarioSpec::parse(bad).is_err(), "'{bad}' must fail");
        }
    }

    #[test]
    fn spec_parse_synth_families() {
        let s = ScenarioSpec::parse("synth:mixed:12:9").unwrap();
        assert_eq!(s.name, "synth-mixed12-s9");
        assert_eq!(s.set.len(), 12);
        assert_eq!(s.mem, MemoryTech::Rram, "synth defaults to rram");
        assert_eq!(s.agg, Aggregation::Max);
        let t = ScenarioSpec::parse("synth:transformer:5:3:sram:mean").unwrap();
        assert_eq!(t.name, "synth-transformer5-s3");
        assert_eq!(t.mem, MemoryTech::Sram);
        assert_eq!(t.agg, Aggregation::Mean);
        // same token → bit-identical family; different seed → different name
        let a = ScenarioSpec::parse("synth:cnn:4:1:rram").unwrap();
        let b = ScenarioSpec::parse("synth:cnn:4:1:rram").unwrap();
        assert_eq!(a.set.names(), b.set.names());
        for (wa, wb) in a.set.workloads.iter().zip(&b.set.workloads) {
            assert_eq!(wa.layers.len(), wb.layers.len());
            assert_eq!(wa.total_weights(), wb.total_weights());
        }
        for bad in [
            "synth:mixed",            // too few fields
            "synth:gan:10:1",         // unknown distribution
            "synth:cnn:0:1",          // empty population
            "synth:cnn:10:1:dram",    // unknown tech
            "synth:cnn:10:1:rram:high", // corner needs accuracy baselines
            "synth:cnn:10:x",         // bad seed
        ] {
            assert!(ScenarioSpec::parse(bad).is_err(), "'{bad}' must fail");
        }
    }

    #[test]
    fn spec_parse_file_tokens() {
        let dir = std::env::temp_dir().join(format!("imcopt-spec-file-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.json");
        let w = crate::workloads::by_name("alexnet").unwrap();
        std::fs::write(&path, crate::ingest::workload_to_json(&w).to_string()).unwrap();
        let spec = format!("{}+resnet18:rram", path.display());
        let s = ScenarioSpec::parse(&spec).unwrap();
        assert_eq!(s.set.names(), vec!["alexnet", "resnet18"]);
        assert!(ScenarioSpec::parse("missing/net.json:rram").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spec_parse_handles_corners() {
        let s = ScenarioSpec::parse("resnet18+alexnet:rram:high").unwrap();
        assert_eq!(s.corner, Some(Corner::High));
        assert_eq!(s.agg, Aggregation::Max, "RRAM default still applies");
        assert_eq!(
            s.objective().kind,
            ObjectiveKind::EdapAccuracy,
            "a pinned corner makes the objective accuracy-aware"
        );
        // corner and aggregation compose in either order
        let a = ScenarioSpec::parse("resnet18:rram:mean:low").unwrap();
        let b = ScenarioSpec::parse("resnet18:rram:low:mean").unwrap();
        assert_eq!(a.corner, Some(Corner::Low));
        assert_eq!(a.agg, Aggregation::Mean);
        assert_eq!(a.corner, b.corner);
        assert_eq!(a.agg, b.agg);
        // corner-free specs keep the plain EDAP objective
        let plain = ScenarioSpec::parse("resnet18:rram").unwrap();
        assert!(plain.corner.is_none());
        assert_eq!(plain.objective().kind, ObjectiveKind::Edap);
    }

    #[test]
    fn paper_specs_match_genmatrix_setups() {
        let specs = paper_specs();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "cnn4");
        assert_eq!(specs[0].set.len(), 4);
        assert_eq!(specs[0].mem, MemoryTech::Rram);
        assert_eq!(specs[1].name, "all9");
        assert_eq!(specs[1].set.len(), 9);
        assert_eq!(specs[1].mem, MemoryTech::Sram);
        for spec in &specs {
            assert_eq!(spec.objective().kind, ObjectiveKind::Edap);
            assert_eq!(spec.objective().agg, spec.agg);
        }
    }
}
