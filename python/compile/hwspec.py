"""Hardware-model constants — the Python mirror of
``rust/src/model/consts.rs`` (and parts of ``accuracy/mod.rs``).

The AOT-compiled JAX/Pallas fitness evaluator must agree with the native
Rust evaluator to <=0.5% relative; both implement the closed-form model of
DESIGN.md §3 from the constants below. ``python/tests/test_hwspec_sync.py``
parses the Rust source and asserts every shared value matches, so a change
on either side fails the build rather than silently skewing results.
"""

# ---- bit widths -------------------------------------------------------------
IN_BITS = 8.0
W_BITS = 8.0

# ---- per-event energies (J) at 32 nm, 1.0 V --------------------------------
E_CELL_RRAM = 0.2e-15
E_CELL_SRAM = 0.05e-15
E_ADC_RRAM = 2.0e-12
E_ADC_SRAM = 1.0e-12
E_DRV = 0.05e-12
E_NOC_BYTE = 1.0e-12
E_GLB_BYTE = 0.5e-12
E_DRAM_BYTE = 32.0e-12
E_SRAM_WRITE_BYTE = 0.5e-12
E_DIG_MAC = 0.1e-12

# ---- bandwidth / throughput -------------------------------------------------
DRAM_BW = 25.6e9
NOC_BYTES_PER_CYCLE = 4.0
ADC_CONV_PER_CYCLE = 4.0
DIG_LANES = 128.0
REP_MAX = 8.0

# ---- areas (mm²) at 32 nm ----------------------------------------------------
CELL_F2_RRAM = 4.0
CELL_F2_SRAM = 160.0
ARRAY_OVH = 1.3
ADC_AREA_MM2 = 0.014
DRV_AREA_MM2 = 0.004
MACRO_BUF_AREA_MM2 = 0.004
TILE_BUF_AREA_MM2 = 0.05
ROUTER_AREA_MM2 = 0.15
IO_AREA_MM2 = 2.0
GLB_MM2_PER_MB = 1.6

# ---- leakage / timing ---------------------------------------------------------
P_LEAK_W_PER_MM2 = 1.0e-3
VTH = 0.3
DELAY_ALPHA = 1.3
T_MIN0_NS = 1.0

# ---- constraints ---------------------------------------------------------------
AREA_CONSTR_MM2 = 800.0

# ---- non-ideality model (accuracy/mod.rs) --------------------------------------
SIGMA_POLY = [0.010, 0.080, -0.160, 0.120, -0.030]
IR_COEFF = 0.035
OUT_NOISE = 0.01
QUANT_BITS = 8.0

# ---- interchange contract (space/mod.rs, workloads/mod.rs, runtime/mod.rs) -----
NUM_PARAMS = 10
PARAM_NAMES = [
    "xbar_rows", "xbar_cols", "c_per_tile", "t_per_router", "g_per_chip",
    "bits_cell", "v_step", "t_cycle_ns", "glb_kb", "tech_nm",
]
L_MAX = 512
LAYER_FEATURES = 8  # [k, n, passes, weights, in_bytes, out_bytes, is_dyn, valid]
# (batch, lmax) artifact variants: the short-lmax variants skip the padded
# layer rows (the CNN workloads have <=62 mapped layers vs MobileBERT's
# 336), which the §Perf pass measured as the dominant artifact cost.
FITNESS_VARIANTS = [(64, 128), (256, 128), (64, 512), (256, 512)]

# accuracy proxy static shapes (runtime/mod.rs)
PROXY_DIM = 256
PROXY_BATCH = 8
PROXY_ITERS = 30


def sigma_mean(n: int = 32) -> float:
    """Trapezoid average of the σ(g) polynomial over g in [0,1] — mirrors
    ``accuracy::NoiseSpec::from_design``."""
    total = 0.0
    for i in range(n + 1):
        g = i / n
        w = 0.5 if i in (0, n) else 1.0
        acc, p = 0.0, 1.0
        for c in SIGMA_POLY:
            acc += c * p
            p *= g
        total += w * max(acc, 0.0)
    return total / n
