"""AOT lowering: JAX graphs -> HLO TEXT artifacts + manifest.json.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (behind the
Rust ``xla`` crate) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
The Makefile makes this incremental; Python never runs on the search path.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import hwspec as hw
from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple so the Rust
    side unwraps with ``to_tuple1``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []

    for batch, lmax in hw.FITNESS_VARIANTS:
        name = f"fitness_b{batch}_l{lmax}"
        lowered = jax.jit(model.fitness_graph).lower(
            *model.example_fitness_args(batch, lmax)
        )
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts.append(
            {
                "name": name,
                "file": fname,
                "batch": batch,
                "lmax": lmax,
                "features": hw.LAYER_FEATURES,
                "inputs": ["designs", "layers", "mode"],
                "outputs": 4,
            }
        )
        print(f"wrote {fname} ({len(text)} chars)")

    lowered = jax.jit(model.accproxy_graph).lower(*model.example_accproxy_args())
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "accproxy.hlo.txt"), "w") as f:
        f.write(text)
    artifacts.append(
        {
            "name": "accproxy",
            "file": "accproxy.hlo.txt",
            "dim": hw.PROXY_DIM,
            "batch": hw.PROXY_BATCH,
            "iters": hw.PROXY_ITERS,
            "inputs": ["w", "x", "noise", "params"],
            "outputs": 1,
        }
    )
    print(f"wrote accproxy.hlo.txt ({len(text)} chars)")

    manifest = {"version": 1, "artifacts": artifacts}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json ({len(artifacts)} artifacts)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
