"""L2: the JAX compute graphs that are AOT-lowered to HLO artifacts.

Two graphs, both calling the L1 Pallas kernels:

* ``fitness_graph`` — batched hardware fitness across a population
  (wraps ``kernels.fitness``; the GA's hot loop).
* ``accproxy_graph`` — mean noisy-crossbar relative error over 30 noise
  iterations (wraps ``kernels.crossbar``; the Fig. 8 accuracy proxy).

Python only ever runs at ``make artifacts`` time; the Rust coordinator
executes the lowered HLO through PJRT at search time.
"""

import jax.numpy as jnp

from . import hwspec as hw
from .kernels import crossbar, fitness


def fitness_graph(designs, layers, mode):
    """(designs [B,10], layers [L_MAX,8], mode [4]) -> [B,4]."""
    return fitness.fitness(designs, layers, mode)


def accproxy_graph(w, x, noise, params):
    """(w [P,P], x [XB,P], noise [I,P,P], params [4]) -> scalar mean ε."""
    return crossbar.mean_eps(w, x, noise, params)


def example_fitness_args(batch, lmax=None):
    """ShapeDtypeStructs for lowering a fitness artifact."""
    import jax

    return (
        jax.ShapeDtypeStruct((batch, hw.NUM_PARAMS), jnp.float32),
        jax.ShapeDtypeStruct((lmax or hw.L_MAX, hw.LAYER_FEATURES), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    )


def example_accproxy_args():
    """ShapeDtypeStructs for lowering the accuracy-proxy artifact."""
    import jax

    return (
        jax.ShapeDtypeStruct((hw.PROXY_DIM, hw.PROXY_DIM), jnp.float32),
        jax.ShapeDtypeStruct((hw.PROXY_BATCH, hw.PROXY_DIM), jnp.float32),
        jax.ShapeDtypeStruct((hw.PROXY_ITERS, hw.PROXY_DIM, hw.PROXY_DIM), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    )
