"""Pure-jnp oracles (no Pallas) for the L1 kernels.

``fitness_ref`` is the reference implementation of the batched hardware
evaluator — the same closed-form model as ``rust/src/model/mod.rs`` — and
``crossbar_eps_ref`` is the reference noisy-crossbar error measurement.
pytest holds the Pallas kernels to these oracles; the Rust integration
suite holds the AOT artifacts to the native Rust evaluator. Together the
chain pins Pallas == jnp == Rust.
"""

import jax.numpy as jnp

from .. import hwspec as hw


# --------------------------------------------------------------------------
# fitness reference
# --------------------------------------------------------------------------

def derived_params(designs, mode):
    """Per-design derived quantities shared by the reference and the Pallas
    wrapper (mirrors ``model::DesignView``).

    designs: [B, 10] raw decoded vectors (v_step already in volts).
    mode: [4] with mode[0] = 1.0 for SRAM.
    Returns a dict of [B] arrays.
    """
    is_sram = mode[0] > 0.5
    rows = designs[:, 0]
    cols = designs[:, 1]
    m = designs[:, 2]
    t = designs[:, 3]
    g = designs[:, 4]
    bits = jnp.where(is_sram, 1.0, designs[:, 5])
    v = designs[:, 6]
    tc = designs[:, 7] * 1e-9
    glb_bytes = designs[:, 8] * 1024.0
    tech = designs[:, 9]
    dpw = jnp.ceil(hw.W_BITS / bits)
    return dict(
        is_sram=is_sram,
        rows=rows,
        cols=cols,
        macros=m * t * g,
        tiles=t * g,
        groups=g,
        v=v,
        tc=tc,
        glb_bytes=glb_bytes,
        tech=tech,
        dpw=dpw,
        s_e=(tech / 32.0) * v * v,
        s_a=(tech / 32.0) ** 2,
        # broadcast to per-design arrays so the Pallas kernel (which
        # receives them as matrix columns) and the reference share shapes
        e_cell=jnp.where(is_sram, hw.E_CELL_SRAM, hw.E_CELL_RRAM) * jnp.ones_like(rows),
        e_adc=jnp.where(is_sram, hw.E_ADC_SRAM, hw.E_ADC_RRAM) * jnp.ones_like(rows),
        t_cycle_ns=designs[:, 7],
    )


def t_min_ns(v, tech):
    """Alpha-power minimum cycle time (mirrors ``consts::t_min_ns``)."""
    def delay(x):
        return x / jnp.maximum(x - hw.VTH, 0.05) ** hw.DELAY_ALPHA

    return hw.T_MIN0_NS * jnp.sqrt(tech / 32.0) * delay(v) / delay(1.0)


def area_mm2(dp):
    """Chip area (mirrors ``NativeEvaluator::area_view``)."""
    f_um = dp["tech"] * 1e-3
    cell_f2 = jnp.where(dp["is_sram"], hw.CELL_F2_SRAM, hw.CELL_F2_RRAM)
    cell_mm2 = cell_f2 * f_um * f_um * 1e-6
    array = dp["rows"] * dp["cols"] * cell_mm2 * hw.ARRAY_OVH
    macro = array + (hw.ADC_AREA_MM2 + hw.DRV_AREA_MM2 + hw.MACRO_BUF_AREA_MM2) * dp["s_a"]
    m_per_tile = dp["macros"] / dp["tiles"]
    tile = m_per_tile * macro + hw.TILE_BUF_AREA_MM2 * dp["s_a"]
    glb_area = dp["glb_bytes"] / (1024.0 * 1024.0) * hw.GLB_MM2_PER_MB * dp["s_a"]
    return (
        dp["tiles"] * tile
        + dp["groups"] * hw.ROUTER_AREA_MM2 * dp["s_a"]
        + glb_area
        + hw.IO_AREA_MM2
    )


def mapping(dp, layers):
    """Crossbar demand per design x layer: xb [B, L], sum/max over valid
    static layers (mirrors the mapping pass in ``NativeEvaluator``)."""
    k = layers[:, 0][None, :]
    n = layers[:, 1][None, :]
    is_dyn = layers[:, 6][None, :]
    valid = layers[:, 7][None, :]
    rows = dp["rows"][:, None]
    cols = dp["cols"][:, None]
    dpw = dp["dpw"][:, None]
    xb = jnp.ceil(k / rows) * jnp.ceil(n * dpw / cols)
    static_mask = valid * (1.0 - is_dyn)
    xb = xb * static_mask
    return xb, xb.sum(axis=1), xb.max(axis=1)


def layer_costs(dp, layers, sum_xb):
    """Per-(design, layer) energy & latency contributions [B, L] — the
    compute the L1 Pallas fitness kernel performs. Mirrors
    ``static_layer_cost`` + ``dynamic_layer_cost``."""
    k = layers[:, 0][None, :]
    n = layers[:, 1][None, :]
    passes = layers[:, 2][None, :]
    weights = layers[:, 3][None, :]
    in_b = layers[:, 4][None, :]
    out_b = layers[:, 5][None, :]
    is_dyn = layers[:, 6][None, :]
    valid = layers[:, 7][None, :]

    rows = dp["rows"][:, None]
    cols = dp["cols"][:, None]
    dpw = dp["dpw"][:, None]
    macros = dp["macros"][:, None]
    tiles = dp["tiles"][:, None]
    groups = dp["groups"][:, None]
    tc = dp["tc"][:, None]
    glb_bytes = dp["glb_bytes"][:, None]
    s_e = dp["s_e"][:, None]
    e_cell = dp["e_cell"][:, None]
    e_adc = dp["e_adc"][:, None]
    # normalize is_sram (scalar bool in the reference, per-design float
    # column inside the Pallas kernel) to [B, 1]
    is_sram = (
        jnp.zeros_like(dp["rows"]) + jnp.asarray(dp["is_sram"], dtype=jnp.float32)
    )[:, None]

    ndpw = n * dpw
    xb_r = jnp.ceil(k / rows)
    xb_c = jnp.ceil(ndpw / cols)
    xb = xb_r * xb_c

    # replication: RRAM uniform over the resident model; SRAM per layer;
    # both capped by the broadcast/reduction fan-out limit REP_MAX
    rep_rram = jnp.clip(
        jnp.floor(macros / jnp.maximum(sum_xb[:, None], 1.0)), 1.0, hw.REP_MAX
    )
    rep_sram = jnp.clip(jnp.floor(macros / jnp.maximum(xb, 1.0)), 1.0, hw.REP_MAX)
    rep = jnp.where(is_sram > 0.5, rep_sram, rep_rram)

    # swapping engages for SRAM when the model does not fit
    swapping = is_sram * jnp.where(sum_xb[:, None] > macros, 1.0, 0.0)

    # ---- static layer ------------------------------------------------------
    # ADC sweeps the macro's *physical* columns; drivers bias the full
    # allocated row span — under-utilization wastes energy/latency (the
    # crossbar-size/workload coupling; mirrors static_layer_cost in Rust).
    lat_compute = (
        jnp.ceil(passes / rep)
        * hw.IN_BITS
        * jnp.ceil(cols / hw.ADC_CONV_PER_CYCLE)
        * tc
    )
    e_array = passes * hw.IN_BITS * k * ndpw * e_cell * s_e
    conversions = passes * hw.IN_BITS * xb_r * (xb_c * cols)
    e_adc_total = conversions * e_adc * s_e
    e_drv = passes * hw.IN_BITS * (xb_r * rows) * xb_c * hw.E_DRV * s_e

    swap_bytes = swapping * weights
    e_swap = swap_bytes * (hw.E_DRAM_BYTE + hw.E_SRAM_WRITE_BYTE)
    lat_swap = swap_bytes / hw.DRAM_BW

    io_bytes = in_b + out_b
    noc_bytes = io_bytes + swap_bytes
    hops = jnp.sqrt(groups)
    lat_noc = noc_bytes * hops * tc / (hw.NOC_BYTES_PER_CYCLE * groups)
    e_noc = noc_bytes * hops * hw.E_NOC_BYTE * s_e
    e_glb = (io_bytes + swap_bytes) * hw.E_GLB_BYTE * s_e

    spill = jnp.maximum(io_bytes - glb_bytes, 0.0)
    e_spill = 2.0 * spill * hw.E_DRAM_BYTE
    lat_spill = 2.0 * spill / hw.DRAM_BW

    e_static = e_array + e_adc_total + e_drv + e_swap + e_noc + e_glb + e_spill
    lat_static = lat_compute + lat_swap + lat_noc + lat_spill

    # ---- dynamic layer (digital vector units) --------------------------------
    macs = k * n * passes
    lat_dig = macs / (tiles * hw.DIG_LANES) * tc
    e_dig = macs * hw.E_DIG_MAC * s_e
    e_dynamic = (
        e_dig
        + io_bytes * hops * hw.E_NOC_BYTE * s_e
        + io_bytes * hw.E_GLB_BYTE * s_e
    )
    lat_dynamic = lat_dig + io_bytes * hops * tc / (hw.NOC_BYTES_PER_CYCLE * groups)

    e = jnp.where(is_dyn > 0.5, e_dynamic, e_static) * valid
    lat = jnp.where(is_dyn > 0.5, lat_dynamic, lat_static) * valid
    return e, lat


def fitness_ref(designs, layers, mode):
    """Full reference fitness: [B,10] x [L,8] x [4] -> [B,4]."""
    dp = derived_params(designs, mode)
    area = area_mm2(dp)
    _xb, sum_xb, max_xb = mapping(dp, layers)
    e_l, lat_l = layer_costs(dp, layers, sum_xb)
    energy = e_l.sum(axis=1)
    latency = lat_l.sum(axis=1)
    # leakage
    p_leak = hw.P_LEAK_W_PER_MM2 * jnp.sqrt(32.0 / dp["tech"]) * dp["v"] * area
    energy = energy + p_leak * latency

    capacity_ok = jnp.where(
        dp["is_sram"], max_xb <= dp["macros"], sum_xb <= dp["macros"]
    )
    timing_ok = dp["t_cycle_ns"] >= t_min_ns(dp["v"], dp["tech"])
    feasible = capacity_ok & timing_ok & (area <= hw.AREA_CONSTR_MM2)
    return jnp.stack([energy, latency, area, feasible.astype(jnp.float32)], axis=-1)


# --------------------------------------------------------------------------
# noisy crossbar reference
# --------------------------------------------------------------------------

def sigma_poly(g):
    """σ(g) polynomial, clamped non-negative (mirrors
    ``accuracy::sigma_of_g``)."""
    g = jnp.clip(g, 0.0, 1.0)
    acc = jnp.zeros_like(g)
    p = jnp.ones_like(g)
    for c in hw.SIGMA_POLY:
        acc = acc + c * p
        p = p * g
    return jnp.maximum(acc, 0.0)


def crossbar_eps_one(w, x, nz, params):
    """Relative MVM error for ONE noise draw (shared math for the kernel
    and the reference). All inputs are jnp arrays."""
    sigma_scale, ir, out_noise, qbits = params[0], params[1], params[2], params[3]
    p_dim = w.shape[0]
    y_ideal = x @ w  # [XB, P]
    scale = jnp.max(jnp.abs(y_ideal)) + 1e-9

    # per-element programming noise, scaled so that a design with
    # weight_sigma == sigma_mean reproduces the polynomial exactly
    sig = sigma_poly(jnp.abs(w)) * (sigma_scale / hw.sigma_mean())

    # IR-drop attenuation grows towards the far corner of the array
    r_norm = (jnp.arange(p_dim, dtype=jnp.float32) / p_dim)[:, None]
    c_norm = (jnp.arange(p_dim, dtype=jnp.float32) / p_dim)[None, :]
    att = 1.0 - ir * r_norm * c_norm

    w_noisy = (w + sig * nz) * att
    y = x @ w_noisy
    # 8-bit ADC quantization on the output range
    levels = 2.0 ** qbits
    y = jnp.round(y / scale * (levels / 2.0)) / (levels / 2.0) * scale
    # output-referred noise (reuses the leading noise rows)
    y = y + out_noise * scale * nz[: x.shape[0], :]
    num = jnp.sqrt(jnp.sum((y - y_ideal) ** 2))
    den = jnp.sqrt(jnp.sum(y_ideal ** 2)) + 1e-9
    return num / den


def crossbar_eps_ref(w, x, noise, params):
    """Relative MVM error per noise iteration.

    w: [P,P] weights in [-1,1]; x: [XB,P] inputs; noise: [I,P,P] standard
    normals; params: [sigma_scale, ir_drop, out_noise, quant_bits].
    Returns [I] relative errors (the AOT graph reports the mean).
    """
    return jnp.stack(
        [crossbar_eps_one(w, x, noise[i], params) for i in range(noise.shape[0])]
    )
