"""L1 Pallas kernel: noisy crossbar MVM (paper §IV-H non-idealities).

Models one analog crossbar tile executing ``y = x·W`` under (i)
conductance-dependent Gaussian programming noise with the 4th-order σ(g)
polynomial, (ii) IR-drop attenuation growing towards the far corner of the
array, (iii) 8-bit ADC output quantization and (iv) additive
output-referred noise — the AIHWKIT-style pipeline the paper uses, driven
by pre-drawn noise tensors so the artifact stays deterministic and the
host (Rust) controls the randomness.

Hardware adaptation: the 256×256 f32 weight block (256 KiB) plus one
noise block fits VMEM comfortably; the ``x·W`` contraction targets the MXU
(at bf16 a 128×128-tiled version would sustain ≈60 % MXU utilization —
estimate recorded in DESIGN.md §7). Grid iterates over noise draws.
``interpret=True`` for CPU-PJRT executability.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(w_ref, x_ref, noise_ref, params_ref, out_ref):
    """One grid step = one noise iteration -> scalar relative error."""
    w = w_ref[...]
    x = x_ref[...]
    nz = noise_ref[...][0]  # [1, P, P] block
    params = params_ref[...]
    out_ref[...] = ref.crossbar_eps_one(w, x, nz, params)[None]


def crossbar_eps(w, x, noise, params):
    """Per-iteration relative MVM errors [I] via the Pallas kernel.

    w: [P,P]; x: [XB,P]; noise: [I,P,P]; params: [4].
    Oracle: ``ref.crossbar_eps_ref``.
    """
    iters, p_dim, _ = noise.shape
    xb = x.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(iters,),
        in_specs=[
            pl.BlockSpec((p_dim, p_dim), lambda i: (0, 0)),
            pl.BlockSpec((xb, p_dim), lambda i: (0, 0)),
            pl.BlockSpec((1, p_dim, p_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((iters,), jnp.float32),
        interpret=True,
    )(w, x, noise, params)


def mean_eps(w, x, noise, params):
    """Mean relative error over the noise iterations — the quantity the
    AOT ``accproxy`` artifact exposes to the Rust coordinator."""
    return jnp.mean(crossbar_eps(w, x, noise, params))
