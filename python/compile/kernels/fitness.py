"""L1 Pallas kernel: batched hardware-fitness evaluation.

The paper's compute hot-spot is evaluating a *population* of candidate
designs against every workload (CIMLoop invocations dominating hours of
search time). Re-thought for a tensor machine, the per-(design, layer)
metric contribution is pure element-wise arithmetic over a
``[B_designs, L_layers]`` tile: the population maps to lanes, the layer
axis reduces in-kernel, and the host-side L2 graph handles the cheap
per-design epilogue (leakage, area, feasibility).

VMEM budget per grid step (B=64 block): derived-params ``64×16×4B`` +
layers ``512×8×4B`` + the ``[64,512]`` intermediates ≈ 0.9 MiB — well
under a TPU core's ~16 MiB VMEM. The kernel is VPU-bound (no MXU
contraction). ``interpret=True`` because the CPU PJRT plugin cannot run
Mosaic custom-calls; the lowered HLO is what ships in the AOT artifact.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Derived-params matrix column order (shared between the wrapper below and
# the kernel body).
DP_COLS = [
    "rows", "cols", "dpw", "macros", "tiles", "groups", "v", "tc",
    "glb_bytes", "tech", "s_e", "e_cell", "e_adc", "is_sram", "sum_xb",
    "t_cycle_ns",
]
ND = len(DP_COLS)


def _kernel(dpm_ref, layers_ref, out_ref):
    """One grid step: a [Bb, ND] block of derived design params against the
    full [L, F] layer table -> [Bb, 2] (energy, latency) partial sums."""
    dpm = dpm_ref[...]
    layers = layers_ref[...]
    dp = {name: dpm[:, i] for i, name in enumerate(DP_COLS)}
    e_l, lat_l = ref.layer_costs(dp, layers, dp["sum_xb"])
    out_ref[...] = jnp.stack([e_l.sum(axis=1), lat_l.sum(axis=1)], axis=-1)


def accumulate(designs, layers, mode, block=64):
    """Run the Pallas kernel over the population.

    designs: [B, 10]; layers: [L, F]; mode: [4]. Returns (energy [B],
    latency [B], dp dict, sum_xb, max_xb) — the raw per-design sums before
    the leakage/feasibility epilogue.
    """
    b = designs.shape[0]
    assert b % block == 0, f"population {b} must be a multiple of block {block}"
    l_max, feat = layers.shape

    dp = ref.derived_params(designs, mode)
    _xb, sum_xb, max_xb = ref.mapping(dp, layers)
    dp_for_matrix = dict(dp)
    dp_for_matrix["is_sram"] = jnp.zeros_like(dp["rows"]) + jnp.asarray(
        dp["is_sram"], dtype=jnp.float32
    )
    dp_for_matrix["sum_xb"] = sum_xb
    dpm = jnp.stack([dp_for_matrix[c] for c in DP_COLS], axis=-1)

    out = pl.pallas_call(
        _kernel,
        grid=(b // block,),
        in_specs=[
            pl.BlockSpec((block, ND), lambda i: (i, 0)),
            pl.BlockSpec((l_max, feat), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 2), jnp.float32),
        interpret=True,
    )(dpm, layers)
    return out[:, 0], out[:, 1], dp, sum_xb, max_xb


def fitness(designs, layers, mode, block=64):
    """Full fitness graph: Pallas accumulation + jnp epilogue.

    Mirrors ``rust/src/model/mod.rs`` exactly; the oracle is
    ``ref.fitness_ref``. Returns [B, 4] = (energy J, latency s, area mm²,
    feasible 0/1).
    """
    energy, latency, dp, sum_xb, max_xb = accumulate(designs, layers, mode, block)
    area = ref.area_mm2(dp)
    p_leak = (
        ref.hw.P_LEAK_W_PER_MM2 * jnp.sqrt(32.0 / dp["tech"]) * dp["v"] * area
    )
    energy = energy + p_leak * latency
    capacity_ok = jnp.where(
        dp["is_sram"], max_xb <= dp["macros"], sum_xb <= dp["macros"]
    )
    timing_ok = dp["t_cycle_ns"] >= ref.t_min_ns(dp["v"], dp["tech"])
    feasible = capacity_ok & timing_ok & (area <= ref.hw.AREA_CONSTR_MM2)
    return jnp.stack(
        [energy, latency, area, feasible.astype(jnp.float32)], axis=-1
    )
