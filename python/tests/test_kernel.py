"""Crossbar Pallas kernel vs pure-jnp oracle (hypothesis sweeps shapes and
noise parameters)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import hwspec as hw
from compile.kernels import crossbar, ref


def make_case(seed, p_dim, xb, iters):
    rng = np.random.default_rng(seed)
    w = rng.uniform(-1, 1, (p_dim, p_dim)).astype(np.float32)
    x = rng.uniform(-1, 1, (xb, p_dim)).astype(np.float32)
    noise = rng.standard_normal((iters, p_dim, p_dim)).astype(np.float32)
    return jnp.array(w), jnp.array(x), jnp.array(noise)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    p_dim=st.sampled_from([16, 32, 64, 128]),
    xb=st.sampled_from([1, 4, 8]),
    iters=st.integers(1, 4),
    sigma=st.floats(0.0, 0.15),
    ir=st.floats(0.0, 0.05),
)
def test_pallas_matches_ref(seed, p_dim, xb, iters, sigma, ir):
    w, x, noise = make_case(seed, p_dim, xb, iters)
    params = jnp.array([sigma, ir, hw.OUT_NOISE, hw.QUANT_BITS], jnp.float32)
    got = crossbar.crossbar_eps(w, x, noise, params)
    want = ref.crossbar_eps_ref(w, x, noise, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-7)


def test_eps_monotone_in_sigma():
    w, x, noise = make_case(7, 64, 8, 8)
    eps = []
    for sigma in [0.0, 0.02, 0.05, 0.10]:
        params = jnp.array([sigma, 0.0, 0.0, hw.QUANT_BITS], jnp.float32)
        eps.append(float(jnp.mean(crossbar.crossbar_eps(w, x, noise, params))))
    assert eps == sorted(eps), f"eps not monotone in sigma: {eps}"


def test_eps_monotone_in_ir_drop():
    w, x, noise = make_case(8, 64, 8, 8)
    eps = []
    for ir in [0.0, 0.01, 0.03, 0.08]:
        params = jnp.array([0.0, ir, 0.0, hw.QUANT_BITS], jnp.float32)
        eps.append(float(jnp.mean(crossbar.crossbar_eps(w, x, noise, params))))
    assert eps == sorted(eps), f"eps not monotone in IR drop: {eps}"


def test_zero_noise_leaves_only_quantization():
    w, x, noise = make_case(9, 64, 8, 4)
    params = jnp.array([0.0, 0.0, 0.0, hw.QUANT_BITS], jnp.float32)
    eps = float(jnp.mean(crossbar.crossbar_eps(w, x, noise, params)))
    # 8-bit quantization alone: small but nonzero
    assert 0.0 < eps < 0.02, eps


def test_eps_roughly_matches_analytical_expectation():
    """The kernel-measured error should land within a small factor of the
    closed-form expectation used by the Rust fallback (accuracy::analytical_eps)."""
    w, x, noise = make_case(10, 128, 8, 16)
    sigma = hw.sigma_mean()  # level_factor == 1
    params = jnp.array([sigma, 0.0, 0.0, hw.QUANT_BITS], jnp.float32)
    eps = float(jnp.mean(crossbar.crossbar_eps(w, x, noise, params)))
    assert 0.2 * sigma < eps < 5.0 * sigma, (eps, sigma)


@pytest.mark.parametrize("p_dim,xb", [(hw.PROXY_DIM, hw.PROXY_BATCH)])
def test_artifact_shape_contract(p_dim, xb):
    """The accproxy artifact's exact shapes execute and reduce to a scalar."""
    w, x, noise = make_case(11, p_dim, xb, hw.PROXY_ITERS)
    params = jnp.array([0.03, 0.02, hw.OUT_NOISE, hw.QUANT_BITS], jnp.float32)
    m = crossbar.mean_eps(w, x, noise, params)
    assert m.shape == ()
    assert 0.0 < float(m) < 1.0
