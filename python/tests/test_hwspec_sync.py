"""Cross-language constant synchronization: parse the Rust sources and
assert every shared constant matches ``hwspec.py`` exactly. A drift on
either side fails the build instead of silently skewing the reproduction.
"""

import os
import re

from compile import hwspec as hw

RUST_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "src")


def rust_consts(path):
    """Extract `pub const NAME: f64 = <expr>;` bindings from a Rust file."""
    text = open(path).read()
    out = {}
    for m in re.finditer(
        r"pub const ([A-Z0-9_]+): f64 = ([0-9eE+.\-_]+);", text
    ):
        out[m.group(1)] = float(m.group(2).replace("_", ""))
    return out


def test_model_consts_match():
    consts = rust_consts(os.path.join(RUST_ROOT, "model", "consts.rs"))
    expected = {
        "IN_BITS": hw.IN_BITS,
        "W_BITS": hw.W_BITS,
        "E_CELL_RRAM": hw.E_CELL_RRAM,
        "E_CELL_SRAM": hw.E_CELL_SRAM,
        "E_ADC_RRAM": hw.E_ADC_RRAM,
        "E_ADC_SRAM": hw.E_ADC_SRAM,
        "E_DRV": hw.E_DRV,
        "E_NOC_BYTE": hw.E_NOC_BYTE,
        "E_GLB_BYTE": hw.E_GLB_BYTE,
        "E_DRAM_BYTE": hw.E_DRAM_BYTE,
        "E_SRAM_WRITE_BYTE": hw.E_SRAM_WRITE_BYTE,
        "E_DIG_MAC": hw.E_DIG_MAC,
        "DRAM_BW": hw.DRAM_BW,
        "NOC_BYTES_PER_CYCLE": hw.NOC_BYTES_PER_CYCLE,
        "ADC_CONV_PER_CYCLE": hw.ADC_CONV_PER_CYCLE,
        "DIG_LANES": hw.DIG_LANES,
        "CELL_F2_RRAM": hw.CELL_F2_RRAM,
        "CELL_F2_SRAM": hw.CELL_F2_SRAM,
        "ARRAY_OVH": hw.ARRAY_OVH,
        "ADC_AREA_MM2": hw.ADC_AREA_MM2,
        "DRV_AREA_MM2": hw.DRV_AREA_MM2,
        "MACRO_BUF_AREA_MM2": hw.MACRO_BUF_AREA_MM2,
        "TILE_BUF_AREA_MM2": hw.TILE_BUF_AREA_MM2,
        "ROUTER_AREA_MM2": hw.ROUTER_AREA_MM2,
        "IO_AREA_MM2": hw.IO_AREA_MM2,
        "GLB_MM2_PER_MB": hw.GLB_MM2_PER_MB,
        "P_LEAK_W_PER_MM2": hw.P_LEAK_W_PER_MM2,
        "VTH": hw.VTH,
        "DELAY_ALPHA": hw.DELAY_ALPHA,
        "T_MIN0_NS": hw.T_MIN0_NS,
        "AREA_CONSTR_MM2": hw.AREA_CONSTR_MM2,
    }
    for name, want in expected.items():
        assert name in consts, f"{name} missing from consts.rs"
        got = consts[name]
        assert got == want, f"{name}: rust {got} != python {want}"


def test_accuracy_consts_match():
    path = os.path.join(RUST_ROOT, "accuracy", "mod.rs")
    text = open(path).read()
    m = re.search(r"SIGMA_POLY: \[f64; 5\] = \[([^\]]+)\]", text)
    rust_poly = [float(x.strip()) for x in m.group(1).split(",") if x.strip()]
    assert rust_poly == hw.SIGMA_POLY
    for name, want in [
        ("IR_COEFF", hw.IR_COEFF),
        ("OUT_NOISE", hw.OUT_NOISE),
        ("QUANT_BITS", hw.QUANT_BITS),
    ]:
        m = re.search(rf"pub const {name}: f64 = ([0-9eE+.\-]+);", text)
        assert m, f"{name} missing from accuracy/mod.rs"
        assert float(m.group(1)) == want, name


def test_interchange_contract_matches():
    wl = open(os.path.join(RUST_ROOT, "workloads", "mod.rs")).read()
    assert f"pub const L_MAX: usize = {hw.L_MAX};" in wl
    assert f"pub const LAYER_FEATURES: usize = {hw.LAYER_FEATURES};" in wl
    rt = open(os.path.join(RUST_ROOT, "runtime", "mod.rs")).read()
    assert f"pub const PROXY_DIM: usize = {hw.PROXY_DIM};" in rt
    assert f"pub const PROXY_BATCH: usize = {hw.PROXY_BATCH};" in rt
    assert f"pub const PROXY_ITERS: usize = {hw.PROXY_ITERS};" in rt
    sp = open(os.path.join(RUST_ROOT, "space", "mod.rs")).read()
    assert f"pub const NUM_PARAMS: usize = {hw.NUM_PARAMS};" in sp
    for name in hw.PARAM_NAMES:
        assert f'"{name}"' in sp, f"param {name} missing from space/mod.rs"


def test_sigma_mean_positive_and_small():
    s = hw.sigma_mean()
    assert 0.005 < s < 0.08, s
