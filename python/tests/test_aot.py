"""AOT lowering tests: the graphs lower to HLO text with the exact
parameter/result shapes the Rust runtime contract expects, and execution
of the lowered module matches direct execution."""

import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, hwspec as hw, model


@pytest.fixture(scope="module")
def out_dir():
    with tempfile.TemporaryDirectory() as d:
        aot.build(d)
        yield d


def test_manifest_lists_all_artifacts(out_dir):
    import json

    manifest = json.load(open(os.path.join(out_dir, "manifest.json")))
    names = {a["name"] for a in manifest["artifacts"]}
    expected = {f"fitness_b{b}_l{l}" for b, l in hw.FITNESS_VARIANTS} | {"accproxy"}
    assert names == expected
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(out_dir, a["file"]))
        if a["name"].startswith("fitness"):
            assert (a["batch"], a["lmax"]) in hw.FITNESS_VARIANTS
            assert a["features"] == hw.LAYER_FEATURES
    # at least one variant must cover the full L_MAX depth
    assert any(l == hw.L_MAX for _, l in hw.FITNESS_VARIANTS)


def test_hlo_text_shapes(out_dir):
    text = open(os.path.join(out_dir, f"fitness_b64_l{hw.L_MAX}.hlo.txt")).read()
    # ENTRY computation must consume the contract shapes
    assert re.search(r"f32\[64,10\]", text), "designs input missing"
    assert re.search(rf"f32\[{hw.L_MAX},{hw.LAYER_FEATURES}\]", text)
    assert re.search(r"f32\[4\]", text)
    assert re.search(r"f32\[64,4\]", text), "output missing"
    # tuple-wrapped for the Rust side's to_tuple1
    assert "tuple" in text
    # the short variant consumes the reduced layer tensor
    short = open(os.path.join(out_dir, "fitness_b64_l128.hlo.txt")).read()
    assert re.search(rf"f32\[128,{hw.LAYER_FEATURES}\]", short)


def test_accproxy_hlo_shapes(out_dir):
    text = open(os.path.join(out_dir, "accproxy.hlo.txt")).read()
    assert re.search(rf"f32\[{hw.PROXY_DIM},{hw.PROXY_DIM}\]", text)
    assert re.search(
        rf"f32\[{hw.PROXY_ITERS},{hw.PROXY_DIM},{hw.PROXY_DIM}\]", text
    )


def test_no_mosaic_custom_calls(out_dir):
    """interpret=True Pallas must lower to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    for f in os.listdir(out_dir):
        if f.endswith(".hlo.txt"):
            text = open(os.path.join(out_dir, f)).read()
            assert "mosaic" not in text.lower(), f
            assert "tpu_custom_call" not in text, f


def test_lowered_matches_eager():
    """jit-lowered execution equals direct (eager) graph execution."""
    rng = np.random.default_rng(0)
    designs = np.zeros((64, hw.NUM_PARAMS), np.float32)
    designs[:] = [256, 256, 16, 8, 24, 2, 0.85, 2, 4096, 32]
    layers = np.zeros((hw.L_MAX, hw.LAYER_FEATURES), np.float32)
    layers[0] = [4608, 512, 196, 4608 * 512, 100352, 100352, 0, 1]
    layers[1] = [512, 512, 196, 0, 100352, 100352, 1, 1]
    mode = np.array([0, 0, 0, 0], np.float32)
    del rng
    args = (jnp.array(designs), jnp.array(layers), jnp.array(mode))
    eager = model.fitness_graph(*args)
    compiled = jax.jit(model.fitness_graph).lower(*args).compile()(*args)
    np.testing.assert_allclose(
        np.asarray(eager), np.asarray(compiled), rtol=1e-6
    )
