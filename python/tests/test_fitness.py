"""Fitness Pallas kernel vs pure-jnp oracle; hypothesis sweeps the design
and workload distributions of both memory technologies."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import hwspec as hw
from compile.kernels import fitness, ref

ROWS = [32, 64, 128, 256, 512]
CPT = [4, 8, 16, 32]
TPR = [2, 4, 8, 16]
GPC = [2, 4, 6, 8, 12, 16, 24, 32, 48, 64]
TC = [1, 2, 5, 10]
GLB = [256, 512, 1024, 4096, 16384, 65536]
TECH = [7, 10, 14, 22, 32, 45, 65, 90]


def random_designs(rng, b, tech_fixed=True):
    d = np.zeros((b, hw.NUM_PARAMS), np.float32)
    d[:, 0] = rng.choice(ROWS, b)
    d[:, 1] = rng.choice(ROWS, b)
    d[:, 2] = rng.choice(CPT, b)
    d[:, 3] = rng.choice(TPR, b)
    d[:, 4] = rng.choice(GPC, b)
    d[:, 5] = rng.choice([1, 2, 4], b)
    d[:, 6] = rng.uniform(0.45, 1.3, b)  # volts (decoded)
    d[:, 7] = rng.choice(TC, b)
    d[:, 8] = rng.choice(GLB, b)
    d[:, 9] = 32.0 if tech_fixed else rng.choice(TECH, b)
    return d


def random_layers(rng, n_layers, with_dynamic=True):
    lt = np.zeros((hw.L_MAX, hw.LAYER_FEATURES), np.float32)
    lt[:n_layers, 0] = rng.integers(9, 25088, n_layers)
    lt[:n_layers, 1] = rng.integers(16, 4096, n_layers)
    lt[:n_layers, 2] = rng.integers(1, 12544, n_layers)
    lt[:n_layers, 3] = lt[:n_layers, 0] * lt[:n_layers, 1]
    lt[:n_layers, 4] = rng.integers(64, 1_000_000, n_layers)
    lt[:n_layers, 5] = rng.integers(64, 1_000_000, n_layers)
    if with_dynamic:
        dyn = rng.random(n_layers) < 0.2
        lt[:n_layers, 6] = dyn
        lt[:n_layers, 3] *= 1 - lt[:n_layers, 6]  # dynamic layers carry no weights
    lt[:n_layers, 7] = 1.0
    return lt


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_layers=st.integers(1, hw.L_MAX),
    is_sram=st.booleans(),
    tech_fixed=st.booleans(),
)
def test_pallas_matches_ref(seed, n_layers, is_sram, tech_fixed):
    rng = np.random.default_rng(seed)
    designs = jnp.array(random_designs(rng, 64, tech_fixed))
    layers = jnp.array(random_layers(rng, n_layers))
    mode = jnp.array([1.0 if is_sram else 0.0, 0, 0, 0], jnp.float32)
    got = np.asarray(fitness.fitness(designs, layers, mode))
    want = np.asarray(ref.fitness_ref(designs, layers, mode))
    np.testing.assert_allclose(got, want, rtol=2e-6, atol=1e-12)


def test_block_partitioning_invariant():
    """b=256 with block 64 must equal four independent b=64 calls."""
    rng = np.random.default_rng(3)
    designs = random_designs(rng, 256)
    layers = jnp.array(random_layers(rng, 40))
    mode = jnp.array([0.0, 0, 0, 0], jnp.float32)
    full = np.asarray(fitness.fitness(jnp.array(designs), layers, mode, block=64))
    parts = np.concatenate(
        [
            np.asarray(fitness.fitness(jnp.array(designs[i : i + 64]), layers, mode))
            for i in range(0, 256, 64)
        ]
    )
    np.testing.assert_allclose(full, parts, rtol=1e-6)


def test_padded_layers_contribute_nothing():
    rng = np.random.default_rng(4)
    designs = jnp.array(random_designs(rng, 64))
    l20 = random_layers(rng, 20)
    l20_padded = l20.copy()
    # garbage in invalid rows must be masked out by valid=0
    l20_padded[20:, :6] = 12345.0
    mode = jnp.array([0.0, 0, 0, 0], jnp.float32)
    a = np.asarray(fitness.fitness(designs, jnp.array(l20), mode))
    b = np.asarray(fitness.fitness(designs, jnp.array(l20_padded), mode))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_energy_monotone_in_voltage():
    rng = np.random.default_rng(5)
    base = random_designs(rng, 64)
    layers = jnp.array(random_layers(rng, 30, with_dynamic=False))
    mode = jnp.array([0.0, 0, 0, 0], jnp.float32)
    lo = base.copy()
    hi = base.copy()
    lo[:, 6] = 0.7
    hi[:, 6] = 1.0
    e_lo = np.asarray(fitness.fitness(jnp.array(lo), layers, mode))[:, 0]
    e_hi = np.asarray(fitness.fitness(jnp.array(hi), layers, mode))[:, 0]
    assert (e_lo < e_hi).all()


def test_sram_capacity_uses_max_layer():
    """A chip that holds the largest layer but not the sum must be feasible
    under SRAM (swapping) and infeasible under RRAM."""
    designs = np.zeros((64, hw.NUM_PARAMS), np.float32)
    designs[:] = [512, 512, 32, 8, 16, 1, 0.85, 2, 8192, 32]
    layers = np.zeros((hw.L_MAX, hw.LAYER_FEATURES), np.float32)
    # 30 identical big layers: each needs ceil(4096/512)*ceil(1024*8/512)
    # = 8*16 = 128 macros; sum = 3840 > 4096? macros = 32*8*16 = 4096.
    # Use 40 layers -> sum 5120 > 4096 but max 128 <= 4096.
    for i in range(40):
        layers[i] = [4096, 1024, 64, 4096 * 1024, 1000, 1000, 0, 1]
    f_sram = np.asarray(
        fitness.fitness(
            jnp.array(designs), jnp.array(layers), jnp.array([1.0, 0, 0, 0], jnp.float32)
        )
    )
    f_rram = np.asarray(
        fitness.fitness(
            jnp.array(designs), jnp.array(layers), jnp.array([0.0, 0, 0, 0], jnp.float32)
        )
    )
    assert f_sram[0, 3] == 1.0, "SRAM should swap and stay feasible"
    assert f_rram[0, 3] == 0.0, "RRAM cannot hold the full model"
    # and swapping must cost latency: SRAM slower than same-shape RRAM
    assert f_sram[0, 1] > f_rram[0, 1]
