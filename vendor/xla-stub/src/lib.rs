//! Offline stub of the `xla` crate API surface used by imcopt's PJRT
//! runtime (`rust/src/runtime`).
//!
//! Purpose: let `cargo build/clippy/test --features pjrt` compile the
//! feature-gated engine in environments without the real XLA toolchain,
//! so that code path cannot rot silently (the CI matrix builds it).
//! Every constructor returns [`Error::StubOnly`], so `Engine::load` fails
//! with an actionable message and all callers fall back to the native
//! analytical evaluator. To execute the AOT artifacts for real, point the
//! root `Cargo.toml`'s `xla` dependency at the actual crate (offline
//! registry or vendored checkout) instead of this stub.
//!
//! Method signatures mirror exactly the calls the engine makes:
//! `PjRtClient::cpu`/`compile`, `HloModuleProto::from_text_file`,
//! `XlaComputation::from_proto`, `Literal::vec1`/`reshape`/`to_tuple1`/
//! `to_vec`, `PjRtLoadedExecutable::execute` and
//! `PjRtBuffer::to_literal_sync`.

#![allow(dead_code)]

use std::fmt;

/// The stub's only error: the real XLA runtime is not linked.
#[derive(Debug)]
pub enum Error {
    StubOnly,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla stub: real XLA toolchain not linked (vendor/xla-stub); \
             point Cargo.toml's `xla` dependency at the real crate"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (never instantiable in the stub).
pub struct PjRtClient(());

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

/// Device buffer handle.
pub struct PjRtBuffer(());

/// XLA computation (buildable; compiling it fails).
pub struct XlaComputation(());

/// Parsed HLO module proto.
pub struct HloModuleProto(());

/// Host literal (buildable; device transfers fail).
pub struct Literal(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::StubOnly)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::StubOnly)
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::StubOnly)
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::StubOnly)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::StubOnly)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::StubOnly)
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::StubOnly)
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::StubOnly)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_closed() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        let msg = format!("{}", Error::StubOnly);
        assert!(msg.contains("xla stub"));
    }
}
